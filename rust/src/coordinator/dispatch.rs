//! The continuous-batching inference pool — the paper's §3.3
//! "multi-process parallel processing" rebuilt as an EnergonAI-style
//! **step-level scheduler**.
//!
//! [`InferencePool::start`] spawns `cfg.workers` OS threads.  Each
//! worker constructs **its own backend + engine** inside its thread
//! plus a sampler seeded from `derive_seed(seed, worker)`, then runs a
//! step loop over [`crate::engine::DecodeSession`]s:
//!
//! 1. seed a session from ONE queued [`Batch`] (the dynamic batcher's
//!    bucket grouping still shapes arrivals);
//! 2. per iteration: check per-request **deadline/cancellation** at the
//!    step boundary, run one decode step, stream the emitted tokens as
//!    [`PoolEvent::Tokens`], retire finished rows at EOS
//!    ([`PoolEvent::Finished`]), then **admit** waiting requests into
//!    the freed slots and keep stepping — no request waits for the
//!    slowest member of a static batch.
//!
//! ## Admission policy
//!
//! Between steps (and only there — admission mid-step would tear the
//! KV state) a worker pulls queued requests while ALL of these hold:
//!
//! - **batch cap**: live rows + accepted candidates < `batch.max_batch`;
//! - **token cap**: summed `need_seq` (prompt + generation budget) of
//!   live rows + candidates stays within `batch.max_batch_tokens`
//!   (when nonzero);
//! - **engine feasibility**
//!   ([`crate::engine::DecodeSession::can_admit`]): with the paged KV
//!   path (the default), the session's block pool must hold free
//!   blocks for the candidate's prompt PLUS its full generation
//!   budget (the decode reservation) — **capacity-aware scheduling**:
//!   a candidate that does not fit queues until retirements free
//!   blocks, and the time the queue head spends blocked this way is
//!   metered as `blocked_on_capacity`.  With contiguous caches the
//!   check is bucket feasibility instead: some compiled (batch, seq)
//!   bucket covers the grown batch.
//!
//! ## Scheduling order, priorities, preemption
//!
//! Candidates wait in an ordered [`PendingQueue`]: **(priority desc,
//! deadline asc — EDF, arrival asc)**.  All-default workloads (every
//! request `Interactive`, no deadlines) drain exactly FIFO, the
//! pre-priority behavior.  Both the seed loop and between-step
//! admission scan that order with SKIP semantics — a candidate that
//! does not fit right now is stepped over, not a round-stopper, so a
//! small request never starves behind a large head the pool cannot
//! place yet (skipped candidates keep their queue rank).
//!
//! Under paged-KV capacity pressure, an arrival may **preempt** live
//! rows of *strictly lower* priority: the victim is retired with
//! [`FinishReason::Preempted`] (its blocks return through the normal
//! retirement path), its tokens-so-far are folded into its prompt, and
//! it is requeued to resume via one admission prefill — greedy token
//! streams are bitwise-identical across evict/resume because the
//! resumed prefill replays the exact same context.  Equal priorities
//! never preempt each other, so default workloads never preempt at
//! all.  Preemption is NOT terminal: the client stream just pauses.
//!
//! Greedy token streams are unaffected by admission timing — rows are
//! independent, and both the paged new-row prefill and the legacy
//! batch-wide re-prefill reproduce decode logits exactly
//! (property-tested).  `cfg.continuous = false` disables between-step
//! admission (static batching, the pre-redesign behavior) for A/B
//! benches.
//!
//! Every request yields EXACTLY ONE terminal event —
//! [`PoolEvent::Finished`] or [`PoolEvent::Failed`] (engine errors,
//! cancellation, deadline expiry) — so downstream reply channels never
//! observe a silent drop.  The contract survives a worker crash: every
//! request a worker owns sits in a shared in-flight registry from pull
//! to terminal event, and [`InferencePool::join`] catches a panicked
//! worker at join and drains its registry entries into typed
//! `engine_error` failures instead of propagating the panic.  With
//! `workers == 1` and greedy sampling, pooled output tokens are
//! identical to the sequential executor's.
//!
//! Shutdown: the pool input disconnects when every
//! [`InferencePool::input`] clone AND the pool's own handle are
//! dropped; workers then drain, emit their [`WorkerReport`], and exit.
//! [`InferencePool::join`] merges the per-worker reports into one
//! [`PoolReport`].

use std::collections::HashMap;
use std::sync::{mpsc, Arc, Mutex, PoisonError};
use std::time::{Duration, Instant};

use super::batcher::Batch;
use super::engine_input;
use super::queue::PendingQueue;
use super::request::{PreparedRequest, Priority};
use crate::config::ServingConfig;
use crate::engine::{
    build_with_kv as build_engine, sampler_for_worker, DecodeSession,
    Engine, EngineInput, FinishReason, SpecStats,
};
use crate::metrics::{Histogram, Throughput};
use crate::runtime::kv::KvStats;
use crate::runtime::prefix::PrefixStats;
use crate::runtime::{backend_for, Backend, RuntimeStats};
use crate::{Error, Result};

/// Per-request lifecycle events leaving the pool.
pub enum PoolEvent {
    /// Tokens emitted for one request by one decode step (streaming).
    Tokens { id: u64, tokens: Vec<u32>, worker: usize },
    /// Terminal success: the request retired at EOS / budget.
    Finished {
        request: PreparedRequest,
        /// Generated ids (EOS-trimmed) — the full summary.
        generated: Vec<u32>,
        /// Session iterations spent while the request was live.
        steps: usize,
        /// Enqueue -> first streamed token.
        ttft: Option<Duration>,
        /// Paged-KV pool occupancy observed as the request retired
        /// (None when the engine runs contiguous caches) — echoed on
        /// wire replies so clients see cache pressure.
        kv: Option<KvStats>,
        /// Session-cumulative prefix-cache counters observed as the
        /// request retired (None when prefix sharing is off or the
        /// cache discipline is contiguous).
        prefix: Option<PrefixStats>,
        /// Session-cumulative speculative-decoding counters observed
        /// as the request retired (None when speculation is off or
        /// the session shape doesn't support it).
        spec: Option<SpecStats>,
        worker: usize,
    },
    /// Terminal failure: engine error, cancellation, or deadline.
    Failed {
        request: PreparedRequest,
        message: String,
        /// Structured code: `engine_error` | `bad_request` |
        /// `cancelled` | `deadline`.
        code: &'static str,
        worker: usize,
    },
}

/// What one worker did over its lifetime.
pub struct WorkerReport {
    pub worker: usize,
    /// Busy wall time inside decode steps + prefills.
    pub busy: Duration,
    /// Decode sessions run.
    pub sessions: u64,
    /// Decode-session iterations run.
    pub steps: u64,
    /// Requests admitted (total, including session seeds).
    pub admitted: u64,
    /// Requests admitted into an ALREADY-RUNNING session — the
    /// continuous-batching event the step-trace tests assert on.
    pub admitted_mid_session: u64,
    /// Requests that ended in a `Failed` event.
    pub failed_requests: u64,
    /// Requests retired successfully.
    pub retired: u64,
    /// Σ steps over retired requests (steps-per-retire numerator).
    pub retired_steps: u64,
    /// Wall time of each session (seed -> last row retired).
    pub session_latency: Histogram,
    /// Enqueue -> first token, per request retired by this worker.
    pub ttft: Histogram,
    /// Requests + generated tokens completed by this worker.
    pub throughput: Throughput,
    /// This worker's backend counters, with startup compilation that
    /// happened before the ready gate subtracted out.
    pub runtime_stats: RuntimeStats,
    /// Context tokens run through prefill across session seeds AND
    /// mid-session admissions — the admission-cost counter (the paged
    /// path prefills only new rows; the legacy path re-prefills the
    /// whole batch per admission).
    pub admission_prefill_tokens: u64,
    /// Wall time the queue head spent blocked on paged-KV capacity
    /// (free blocks short of its prompt + decode reservation).
    pub blocked_on_capacity: Duration,
    /// Peak paged-KV blocks in use across this worker's sessions.
    pub kv_peak_blocks_in_use: u64,
    /// Paged-KV pool size per session (0 = contiguous caches).
    pub kv_total_blocks: u64,
    /// Live rows this worker evicted to make room for higher-priority
    /// arrivals (each eviction is one resume-later requeue, not a
    /// failure).
    pub preemptions: u64,
    /// Per-iteration service latency: one decode step PLUS the same
    /// iteration's admission prefill.  This is the SLO quantity chunked
    /// prefill bounds — a monolithic admission prefill lands entirely
    /// inside one iteration, a chunked one is spread across many.
    pub step_latency: Histogram,
    /// Prefix-cache probes at admissions (one per admitted prompt when
    /// sharing is on; 0 when off or contiguous).
    pub prefix_lookups: u64,
    /// Admissions that reused at least one cached prefix token.
    pub prefix_hits: u64,
    /// Σ prompt tokens served from cached blocks instead of prefill —
    /// the saved-work counter (`admission_prefill_tokens` shrinks by
    /// exactly this much relative to a no-sharing run).
    pub prefix_tokens_reused: u64,
    /// Draft tokens the speculative decoder proposed for verification.
    pub spec_drafted: u64,
    /// Draft tokens verified-and-accepted (each one a token emitted
    /// without its own decode dispatch).
    pub spec_accepted: u64,
    /// Decode dispatches the accepted drafts made unnecessary.
    pub spec_dispatches_saved: u64,
}

impl WorkerReport {
    fn new(worker: usize) -> Self {
        Self {
            worker,
            busy: Duration::ZERO,
            sessions: 0,
            steps: 0,
            admitted: 0,
            admitted_mid_session: 0,
            failed_requests: 0,
            retired: 0,
            retired_steps: 0,
            session_latency: Histogram::new(),
            ttft: Histogram::new(),
            throughput: Throughput::new(),
            runtime_stats: RuntimeStats::default(),
            admission_prefill_tokens: 0,
            blocked_on_capacity: Duration::ZERO,
            kv_peak_blocks_in_use: 0,
            kv_total_blocks: 0,
            preemptions: 0,
            step_latency: Histogram::new(),
            prefix_lookups: 0,
            prefix_hits: 0,
            prefix_tokens_reused: 0,
            spec_drafted: 0,
            spec_accepted: 0,
            spec_dispatches_saved: 0,
        }
    }
}

/// Paged-KV serving metrics merged across workers (all zero when the
/// engine runs contiguous caches; `admission_prefill_tokens` and
/// `admitted_mid_session` are meaningful on both cache disciplines).
#[derive(Debug, Clone, Copy, Default)]
pub struct KvMetrics {
    /// Σ context tokens prefilled at admissions (seeds included).
    pub admission_prefill_tokens: u64,
    /// Requests admitted into already-running sessions.
    pub admitted_mid_session: u64,
    /// Σ wall time queue heads spent blocked on KV capacity.
    pub blocked_on_capacity: Duration,
    /// Peak blocks in use in any one session pool.
    pub kv_peak_blocks_in_use: u64,
    /// Per-session pool size (max across workers; 0 = contiguous).
    pub kv_total_blocks: u64,
    /// Σ priority preemptions (evict + resume-later) across workers.
    pub preemptions: u64,
    /// Prefix-cache probes at admissions across workers.
    pub prefix_lookups: u64,
    /// Admissions that reused at least one cached prefix token.
    pub prefix_hits: u64,
    /// Σ prompt tokens served from cached blocks instead of prefill.
    pub prefix_tokens_reused: u64,
}

impl KvMetrics {
    /// Fraction of admission probes that reused cached prefix blocks
    /// (0.0 when sharing is off or nothing was admitted).
    pub fn prefix_hit_rate(&self) -> f64 {
        if self.prefix_lookups == 0 {
            0.0
        } else {
            self.prefix_hits as f64 / self.prefix_lookups as f64
        }
    }
}

/// Per-worker reports plus their merged view.
pub struct PoolReport {
    pub workers: Vec<WorkerReport>,
}

impl PoolReport {
    /// Total busy time across workers (can exceed wall time — that is
    /// the point of the pool).
    pub fn busy(&self) -> Duration {
        self.workers.iter().map(|w| w.busy).sum()
    }

    /// Per-session inference latency merged across workers.
    pub fn session_latency(&self) -> Histogram {
        let mut h = Histogram::new();
        for w in &self.workers {
            h.merge(&w.session_latency);
        }
        h
    }

    /// Time-to-first-token merged across workers.
    pub fn ttft(&self) -> Histogram {
        let mut h = Histogram::new();
        for w in &self.workers {
            h.merge(&w.ttft);
        }
        h
    }

    /// Per-iteration (step + same-iteration admission) latency merged
    /// across workers — p99 of this is the SLO bound chunked prefill
    /// exists to shrink.
    pub fn step_latency(&self) -> Histogram {
        let mut h = Histogram::new();
        for w in &self.workers {
            h.merge(&w.step_latency);
        }
        h
    }

    /// Mean decode-session iterations per retired request.
    pub fn steps_per_retire(&self) -> f64 {
        let steps: u64 = self.workers.iter().map(|w| w.retired_steps).sum();
        let retired: u64 = self.workers.iter().map(|w| w.retired).sum();
        if retired == 0 {
            0.0
        } else {
            steps as f64 / retired as f64
        }
    }

    /// Requests admitted into already-running sessions, total.
    pub fn admitted_mid_session(&self) -> u64 {
        self.workers.iter().map(|w| w.admitted_mid_session).sum()
    }

    /// Items/tokens completed, merged across workers.
    pub fn throughput(&self) -> Throughput {
        let mut t = Throughput::new();
        for w in &self.workers {
            t.merge(&w.throughput);
        }
        t
    }

    /// Backend counters merged across the per-worker backends.
    pub fn runtime_stats(&self) -> RuntimeStats {
        let mut s = RuntimeStats::default();
        for w in &self.workers {
            s.merge(&w.runtime_stats);
        }
        s
    }

    /// Speculative-decoding counters merged across workers (all zero
    /// when speculation is off).
    pub fn spec_metrics(&self) -> SpecStats {
        let mut s = SpecStats::default();
        for w in &self.workers {
            s.drafted += w.spec_drafted;
            s.accepted += w.spec_accepted;
            s.dispatches_saved += w.spec_dispatches_saved;
        }
        s
    }

    /// Paged-KV cache metrics merged across workers.
    pub fn kv_metrics(&self) -> KvMetrics {
        let mut m = KvMetrics::default();
        for w in &self.workers {
            m.admission_prefill_tokens += w.admission_prefill_tokens;
            m.admitted_mid_session += w.admitted_mid_session;
            m.blocked_on_capacity += w.blocked_on_capacity;
            m.preemptions += w.preemptions;
            m.kv_peak_blocks_in_use =
                m.kv_peak_blocks_in_use.max(w.kv_peak_blocks_in_use);
            m.kv_total_blocks = m.kv_total_blocks.max(w.kv_total_blocks);
            m.prefix_lookups += w.prefix_lookups;
            m.prefix_hits += w.prefix_hits;
            m.prefix_tokens_reused += w.prefix_tokens_reused;
        }
        m
    }
}

/// Requests currently owned by a worker — pulled off the shared queue
/// but with no terminal event sent yet — keyed by request id and
/// tagged with the owning worker index.  [`InferencePool::join`]
/// drains a panicked worker's entries into typed `Failed` events so
/// the exactly-one-terminal contract survives the crash.
type InFlight = Arc<Mutex<HashMap<u64, (usize, PreparedRequest)>>>;

/// A pool of step-scheduled inference workers consuming [`Batch`]es
/// from a shared queue (see module docs).
pub struct InferencePool {
    input: mpsc::SyncSender<Batch>,
    handles: Vec<std::thread::JoinHandle<WorkerReport>>,
    /// Failsafe clone of the event stream: `join()` emits terminal
    /// failures through it for requests a panicked worker abandoned.
    failsafe: mpsc::SyncSender<PoolEvent>,
    inflight: InFlight,
}

impl InferencePool {
    /// Spawn `cfg.workers` workers, each standing up its own backend +
    /// engine, and block until every worker is ready (startup
    /// compilation done) or return the first startup error.  `out`
    /// receives the per-request [`PoolEvent`] stream.
    pub fn start(
        cfg: &ServingConfig,
        out: mpsc::SyncSender<PoolEvent>,
    ) -> Result<Self> {
        cfg.validate()?;
        let n = cfg.workers;
        // input queue sized so the batcher can run ahead of slow workers
        let (input, rx) = mpsc::sync_channel::<Batch>(cfg.stage_queue.max(n));
        let rx = Arc::new(Mutex::new(rx));
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();

        let inflight: InFlight = Arc::new(Mutex::new(HashMap::new()));
        let mut handles = Vec::with_capacity(n);
        for worker in 0..n {
            let cfg = cfg.clone();
            let rx = rx.clone();
            let out = out.clone();
            let ready_tx = ready_tx.clone();
            let inflight = inflight.clone();
            let spawned = std::thread::Builder::new()
                .name(format!("inference-{worker}"))
                .spawn(move || {
                    worker_main(worker, cfg, rx, out, ready_tx, inflight)
                });
            match spawned {
                Ok(handle) => handles.push(handle),
                Err(e) => {
                    // OS refused the thread: unwind instead of
                    // panicking — close the queue so the workers that
                    // DID spawn drain and exit, reap them, and hand
                    // the caller a typed error
                    drop(input);
                    for h in handles {
                        let _ = h.join();
                    }
                    return Err(Error::Io(e));
                }
            }
        }
        let failsafe = out;
        drop(ready_tx);

        // Ready gate: fail fast (typed) if any worker cannot stand up
        // its backend/engine, instead of leaving clients to hang.
        let mut startup_err = None;
        for _ in 0..n {
            match ready_rx.recv() {
                Ok(Ok(())) => {}
                Ok(Err(e)) => {
                    if startup_err.is_none() {
                        startup_err = Some(e);
                    }
                }
                Err(_) => {
                    if startup_err.is_none() {
                        startup_err =
                            Some(Error::Shutdown("worker died at startup"));
                    }
                }
            }
        }
        if let Some(e) = startup_err {
            // unblock and reap the workers that did start
            drop(input);
            for h in handles {
                let _ = h.join();
            }
            return Err(e);
        }
        Ok(Self { input, handles, failsafe, inflight })
    }

    /// A clonable submission handle.  The pool drains and shuts down
    /// once every clone AND the pool itself are dropped/joined.
    pub fn input(&self) -> mpsc::SyncSender<Batch> {
        self.input.clone()
    }

    /// Number of workers.
    pub fn workers(&self) -> usize {
        self.handles.len()
    }

    /// Close the pool's own input handle, wait for the workers to
    /// drain, and merge their reports.  A panicked worker does NOT
    /// propagate: its in-flight requests are drained into typed
    /// `engine_error` failures (exactly-one-terminal survives the
    /// crash) and it contributes an empty report; surviving workers
    /// merge normally.
    pub fn join(self) -> PoolReport {
        let Self { input, handles, failsafe, inflight } = self;
        drop(input);
        let mut workers: Vec<WorkerReport> = Vec::with_capacity(handles.len());
        for (idx, h) in handles.into_iter().enumerate() {
            match h.join() {
                Ok(r) => workers.push(r),
                Err(_) => {
                    // handle order == spawn order, so `idx` is the
                    // dead worker's index; its report is gone, but the
                    // requests it owned must still see one terminal
                    // event each
                    let mut report = WorkerReport::new(idx);
                    let dead: Vec<PreparedRequest> = {
                        let mut g = inflight
                            .lock()
                            .unwrap_or_else(PoisonError::into_inner);
                        let ids: Vec<u64> = g
                            .iter()
                            .filter(|(_, (w, _))| *w == idx)
                            .map(|(id, _)| *id)
                            .collect();
                        ids.into_iter()
                            .filter_map(|id| g.remove(&id).map(|(_, r)| r))
                            .collect()
                    };
                    for r in dead {
                        // downstream may itself be gone — best effort
                        let _ = send_failed(
                            &failsafe,
                            &mut report,
                            idx,
                            &inflight,
                            r,
                            "inference worker panicked".into(),
                            "engine_error",
                        );
                    }
                    workers.push(report);
                }
            }
        }
        workers.sort_by_key(|w| w.worker);
        PoolReport { workers }
    }
}

/// Worker-side bookkeeping for one live request.
struct RowMeta {
    req: PreparedRequest,
    first_token: Option<Instant>,
}

/// Emit a terminal `Failed` event; false when downstream disconnected.
/// Terminal means the request leaves the crash-recovery registry too.
fn send_failed(
    out: &mpsc::SyncSender<PoolEvent>,
    report: &mut WorkerReport,
    worker: usize,
    inflight: &InFlight,
    request: PreparedRequest,
    message: String,
    code: &'static str,
) -> bool {
    inflight
        .lock()
        .unwrap_or_else(PoisonError::into_inner)
        .remove(&request.id);
    report.failed_requests += 1;
    out.send(PoolEvent::Failed { request, message, code, worker }).is_ok()
}

/// Take ownership of freshly pulled arrivals: register each in the
/// crash-recovery registry, then rank it into the pending queue.
fn take_arrivals(
    pending: &mut PendingQueue,
    inflight: &InFlight,
    worker: usize,
    requests: Vec<PreparedRequest>,
) {
    let mut g = inflight.lock().unwrap_or_else(PoisonError::into_inner);
    for r in requests {
        g.insert(r.id, (worker, r.clone()));
        pending.push(r);
    }
}

/// Evict live rows of strictly lower priority than `cand_priority`
/// until `session.can_admit(want)` holds; returns whether admission is
/// now possible.  Victims go lowest-priority first, youngest
/// (latest-enqueued) first — the least progress to replay.  Each
/// eviction surfaces as [`FinishReason::Preempted`] at the next drain,
/// where it is REQUEUED (never failed), so the victim resumes once
/// capacity returns.
///
/// A feasibility gate runs first: unless evicting EVERY eligible
/// victim would free enough blocks, nobody is evicted at all — an
/// oversized candidate must not thrash the pool (evict, still not
/// fit, watch the victims re-admit, evict again …).
fn preempt_until_admittable(
    session: &mut dyn DecodeSession,
    meta: &HashMap<u64, RowMeta>,
    cand_priority: Priority,
    want: &[EngineInput],
    report: &mut WorkerReport,
) -> bool {
    let Some(st) = session.kv_stats() else {
        return false; // contiguous caches: blocks never come back early
    };
    // a live row's block footprint is its full admission reservation
    // (prompt + decode budget), which requeues preserve
    let mut victims: Vec<(Priority, Instant, u64, usize)> = meta
        .values()
        .filter(|m| m.req.priority < cand_priority)
        .map(|m| {
            (
                m.req.priority,
                m.req.enqueued,
                m.req.id,
                m.req.need_seq().div_ceil(st.block_size),
            )
        })
        .collect();
    if victims.is_empty() {
        return false;
    }
    let needed: usize = want
        .iter()
        .map(|w| {
            (w.prompt.len() + w.max_new_tokens).div_ceil(st.block_size)
        })
        .sum();
    let reclaimable: usize = victims.iter().map(|v| v.3).sum();
    if st.free_blocks + reclaimable < needed {
        return false;
    }
    victims.sort_by(|a, b| {
        a.0.cmp(&b.0).then(b.1.cmp(&a.1)).then(b.2.cmp(&a.2))
    });
    for (_, _, id, _) in victims {
        if !session.retire(id, FinishReason::Preempted) {
            continue; // already retired this step (EOS / deadline / …)
        }
        report.preemptions += 1;
        if session.can_admit(want) {
            return true;
        }
    }
    session.can_admit(want)
}

/// Drain retired rows out of the session into terminal events — or,
/// for [`FinishReason::Preempted`] rows, back into the pending queue
/// (preemption is not terminal).  False when downstream disconnected.
fn drain_finished(
    session: &mut dyn DecodeSession,
    meta: &mut HashMap<u64, RowMeta>,
    pending: &mut PendingQueue,
    out: &mpsc::SyncSender<PoolEvent>,
    report: &mut WorkerReport,
    worker: usize,
    inflight: &InFlight,
) -> bool {
    // occupancy AFTER the step that retired these rows — what the
    // pool looked like when capacity came back
    let kv = session.kv_stats();
    let prefix = session.prefix_stats();
    let spec = session.spec_stats();
    for fin in session.take_finished() {
        let id = fin.output.request_id;
        let Some(m) = meta.remove(&id) else { continue };
        let ok = match fin.reason {
            FinishReason::Eos | FinishReason::Length => {
                let mut req = m.req;
                // undo the requeue bookkeeping of any preemptions on
                // the way here: the reply carries the ORIGINAL prompt
                // and the stitched pre-eviction + post-resume stream
                let pre = std::mem::take(&mut req.preempted_generated);
                req.prompt.truncate(req.prompt.len() - pre.len());
                req.max_new_tokens += pre.len();
                let mut generated = pre;
                generated.extend(fin.output.generated);
                // TTFT anchors on the FIRST emission ever, which may
                // predate the last eviction
                let first = req.first_emit.or(m.first_token);
                let ttft = first.map(|t| t.duration_since(req.enqueued));
                if let Some(d) = ttft {
                    report.ttft.record(d);
                }
                report.retired += 1;
                report.retired_steps += fin.output.steps as u64;
                report.throughput.record(1, generated.len() as u64);
                inflight
                    .lock()
                    .unwrap_or_else(PoisonError::into_inner)
                    .remove(&id);
                out.send(PoolEvent::Finished {
                    request: req,
                    generated,
                    steps: fin.output.steps,
                    ttft,
                    kv,
                    prefix,
                    spec,
                    worker,
                })
                .is_ok()
            }
            FinishReason::Preempted => {
                // NOT terminal: fold the progress into the prompt so
                // the resumed admission prefill replays the identical
                // context (greedy continuations stay bitwise-equal to
                // the uninterrupted stream) and rank it back into the
                // queue.  It keeps its in-flight registry entry — the
                // request is still this pool's to finish.
                let mut req = m.req;
                let done = fin.output.generated.len();
                req.prompt.extend(fin.output.generated.iter().copied());
                req.preempted_generated.extend(fin.output.generated);
                req.max_new_tokens = req.max_new_tokens.saturating_sub(done);
                req.preemptions += 1;
                req.first_emit = req.first_emit.or(m.first_token);
                pending.push(req);
                true
            }
            FinishReason::Cancelled => send_failed(
                out,
                report,
                worker,
                inflight,
                m.req,
                "request cancelled by client".into(),
                "cancelled",
            ),
            FinishReason::DeadlineExpired => send_failed(
                out,
                report,
                worker,
                inflight,
                m.req,
                "request deadline expired".into(),
                "deadline",
            ),
        };
        if !ok {
            return false;
        }
    }
    true
}

/// Test hook: panic the worker while the hooked request id is live —
/// exercises the panicked-worker failsafe in [`InferencePool::join`].
#[cfg(test)]
static PANIC_ON_REQUEST: std::sync::atomic::AtomicU64 =
    std::sync::atomic::AtomicU64::new(u64::MAX);

#[cfg(test)]
fn panic_if_hooked(meta: &HashMap<u64, RowMeta>) {
    let id = PANIC_ON_REQUEST.load(std::sync::atomic::Ordering::Relaxed);
    if meta.contains_key(&id) {
        panic!("test hook: worker panicked with request {id} in flight");
    }
}

fn worker_main(
    worker: usize,
    cfg: ServingConfig,
    rx: Arc<Mutex<mpsc::Receiver<Batch>>>,
    out: mpsc::SyncSender<PoolEvent>,
    ready_tx: mpsc::Sender<Result<()>>,
    inflight: InFlight,
) -> WorkerReport {
    let mut report = WorkerReport::new(worker);

    // Per-worker backend + engine, constructed on this thread.
    let setup = backend_for(&cfg).and_then(|backend| {
        build_engine(cfg.engine, backend.clone(), cfg.gen, cfg.kv)
            .map(|engine| (backend, engine))
    });
    let (backend, engine) = match setup {
        Ok(pair) => pair,
        Err(e) => {
            let _ = ready_tx.send(Err(e));
            return report;
        }
    };
    if cfg.precompile {
        if let Err(e) = crate::engine::precompile(cfg.engine, backend.as_ref())
        {
            let _ = ready_tx.send(Err(e));
            return report;
        }
    }
    let _ = ready_tx.send(Ok(()));
    // release the gate sender NOW: if a sibling worker panics during
    // startup, the gate must disconnect instead of deadlocking start()
    drop(ready_tx);
    // compilation before the ready gate is startup cost, not steady state
    let compile_before = backend.stats().compile_secs;

    let mut sampler = sampler_for_worker(cfg.sampling, worker as u64);
    let policy = cfg.batch.clone();
    // Paged-KV geometry of a fresh session, for capacity-aware seeding
    // (None = contiguous caches; bucket selection is the only bound).
    let kv_geom = engine.kv_geometry();
    // Carry buffer: arrivals pulled off the queue but not yet admitted
    // (bounded by roughly one batch — we only pull when slots are
    // free), kept in (priority, deadline, arrival) order.
    let mut pending = PendingQueue::new();

    'pool: loop {
        // ---- seed the next session from ONE queued batch -------------
        // The queue mutex is NEVER held while blocking: an idle worker
        // parked inside a blocking recv would stall every other
        // worker's between-step admission on the lock.  Poll + sleep
        // instead (1ms idle granularity, lock held only for the pop).
        if pending.is_empty() {
            let next = {
                rx.lock().unwrap_or_else(PoisonError::into_inner).try_recv()
            };
            match next {
                Ok(b) => {
                    take_arrivals(&mut pending, &inflight, worker, b.requests)
                }
                Err(mpsc::TryRecvError::Empty) => {
                    std::thread::sleep(Duration::from_millis(1));
                    continue;
                }
                Err(mpsc::TryRecvError::Disconnected) => break,
            }
        }
        let mut seed: Vec<PreparedRequest> = Vec::new();
        let mut seed_tokens = 0usize;
        let mut seed_prompt = 0usize; // longest prompt so far
        let mut seed_new = 0usize; // largest generation budget so far
        let mut seed_blocks = 0usize; // paged-KV blocks reserved so far
        let mut scan = 0; // skip-scan cursor over the ordered queue
        while scan < pending.len() {
            let r = pending.get(scan);
            if !seed.is_empty() {
                if seed.len() >= policy.max_batch {
                    break;
                }
                let over_tokens = policy.max_batch_tokens > 0
                    && seed_tokens + r.need_seq() > policy.max_batch_tokens;
                // joint bucket feasibility: the session's conservative
                // need is max(prompt) + max(max_new) — mixed carry-over
                // requests must not fail each other.  Paged-KV: the
                // fresh session's pool must hold every member's prompt
                // + decode reservation.
                let over_bucket = seed_prompt.max(r.prompt.len())
                    + seed_new.max(r.max_new_tokens)
                    > engine.max_seq();
                let over_kv = kv_geom.is_some_and(|(total, bs)| {
                    seed_blocks + r.need_seq().div_ceil(bs) > total
                });
                if over_tokens || over_bucket || over_kv {
                    // skip, don't stop: a later (smaller) candidate may
                    // still fit this seed.  The skipped one keeps its
                    // rank and waits for between-step admission or the
                    // next session.
                    scan += 1;
                    continue;
                }
            }
            let r = pending.remove(scan);
            // worker bookkeeping is keyed by request id; a duplicate
            // would shadow its twin's terminal event, so reject it
            // (server-side ids are unique — this guards direct users)
            if seed.iter().any(|s| s.id == r.id) {
                if !send_failed(
                    &out,
                    &mut report,
                    worker,
                    &inflight,
                    r,
                    "duplicate request id in flight".into(),
                    "bad_request",
                ) {
                    break 'pool;
                }
                continue;
            }
            seed_tokens += r.need_seq();
            seed_prompt = seed_prompt.max(r.prompt.len());
            seed_new = seed_new.max(r.max_new_tokens);
            if let Some((_, bs)) = kv_geom {
                seed_blocks += r.need_seq().div_ceil(bs);
            }
            seed.push(r);
        }
        let inputs: Vec<_> = seed.iter().map(engine_input).collect();
        let t_session = Instant::now();
        let mut session = match engine.start(&inputs) {
            Ok(s) => s,
            Err(e) => {
                let (msg, code) = (e.to_string(), e.code());
                for r in seed {
                    if !send_failed(
                        &out,
                        &mut report,
                        worker,
                        &inflight,
                        r,
                        msg.clone(),
                        code,
                    ) {
                        break 'pool;
                    }
                }
                continue;
            }
        };
        report.busy += t_session.elapsed(); // prefill cost
        report.sessions += 1;
        report.admitted += seed.len() as u64;
        let mut session_prefill = session.prefill_tokens();
        report.admission_prefill_tokens += session_prefill;
        // prefix-cache counters are session-cumulative too: fold deltas
        // into the report the same way as the prefill counter
        let mut session_prefix =
            session.prefix_stats().unwrap_or_default();
        report.prefix_lookups += session_prefix.lookups;
        report.prefix_hits += session_prefix.hits;
        report.prefix_tokens_reused += session_prefix.tokens_reused;
        // speculation counters accrue inside step(); track the
        // session-cumulative value and fold deltas like the prefill
        // counter (zero at the seed — nothing has decoded yet)
        let mut session_spec = session.spec_stats().unwrap_or_default();
        if let Some(st) = session.kv_stats() {
            report.kv_total_blocks =
                report.kv_total_blocks.max(st.total_blocks as u64);
            report.kv_peak_blocks_in_use = report
                .kv_peak_blocks_in_use
                .max(st.used_blocks() as u64);
        }
        // while the queue head is blocked on KV capacity, this holds
        // the instant the blocking was first observed
        let mut blocked_since: Option<Instant> = None;
        let mut meta: HashMap<u64, RowMeta> = seed
            .into_iter()
            .map(|r| (r.id, RowMeta { req: r, first_token: None }))
            .collect();

        // ---- the step loop -------------------------------------------
        loop {
            #[cfg(test)]
            panic_if_hooked(&meta);
            // deadline / cancellation checks at the step boundary
            let now = Instant::now();
            for (id, m) in meta.iter() {
                if m.req.expired(now) {
                    session.retire(*id, FinishReason::DeadlineExpired);
                } else if m.req.cancelled() {
                    session.retire(*id, FinishReason::Cancelled);
                }
            }
            if !drain_finished(
                session.as_mut(),
                &mut meta,
                &mut pending,
                &out,
                &mut report,
                worker,
                &inflight,
            ) {
                break 'pool;
            }
            if session.active() == 0 {
                break;
            }

            // one decode iteration
            let t = Instant::now();
            let events = match session.step(&mut sampler) {
                Ok(ev) => ev,
                Err(e) => {
                    // session is dead: every live request gets a typed
                    // terminal error, never a silent drop
                    let (msg, code) = (e.to_string(), e.code());
                    for (_, m) in meta.drain() {
                        if !send_failed(
                            &out,
                            &mut report,
                            worker,
                            &inflight,
                            m.req,
                            msg.clone(),
                            code,
                        ) {
                            break 'pool;
                        }
                    }
                    break;
                }
            };
            let step_cost = t.elapsed();
            report.busy += step_cost;
            report.steps += 1;
            // chunked prefill spends its prompt budget INSIDE step() —
            // fold freshly prefilled tokens into the admission counter
            // here as well as after admit()
            let pft = session.prefill_tokens();
            report.admission_prefill_tokens +=
                pft.saturating_sub(session_prefill);
            session_prefill = pft;
            if let Some(s) = session.spec_stats() {
                report.spec_drafted +=
                    s.drafted.saturating_sub(session_spec.drafted);
                report.spec_accepted +=
                    s.accepted.saturating_sub(session_spec.accepted);
                report.spec_dispatches_saved += s
                    .dispatches_saved
                    .saturating_sub(session_spec.dispatches_saved);
                session_spec = s;
            }
            let now = Instant::now();
            for ev in events {
                if ev.tokens.is_empty() {
                    continue;
                }
                if let Some(m) = meta.get_mut(&ev.request_id) {
                    if m.first_token.is_none() {
                        m.first_token = Some(now);
                    }
                }
                // offline executors disable the live stream — nothing
                // consumes it there (TTFT was stamped above regardless)
                if !cfg.stream_tokens {
                    continue;
                }
                if out
                    .send(PoolEvent::Tokens {
                        id: ev.request_id,
                        tokens: ev.tokens,
                        worker,
                    })
                    .is_err()
                {
                    break 'pool;
                }
            }
            if !drain_finished(
                session.as_mut(),
                &mut meta,
                &mut pending,
                &out,
                &mut report,
                worker,
                &inflight,
            ) {
                break 'pool;
            }
            if session.active() == 0 {
                report.step_latency.record(step_cost);
                break;
            }

            // ---- admission between steps (continuous batching) -------
            if !cfg.continuous {
                report.step_latency.record(step_cost);
                continue;
            }
            let mut accepted: Vec<PreparedRequest> = Vec::new();
            let mut accepted_inputs = Vec::new();
            let mut capacity_blocked = false;
            let mut live_tokens: usize =
                meta.values().map(|m| m.req.need_seq()).sum();
            let mut scan = 0; // skip-scan cursor over the ordered queue
            loop {
                if session.active() + accepted.len() >= policy.max_batch {
                    break;
                }
                if scan >= pending.len() {
                    // every queued candidate was considered — pull
                    // fresh arrivals while slots are free, rescanning
                    // from the top (new arrivals may outrank skipped
                    // ones)
                    let next = {
                        rx.lock()
                            .unwrap_or_else(PoisonError::into_inner)
                            .try_recv()
                    };
                    match next {
                        Ok(b) => {
                            take_arrivals(
                                &mut pending,
                                &inflight,
                                worker,
                                b.requests,
                            );
                            scan = 0;
                            continue;
                        }
                        Err(_) => break,
                    }
                }
                let cand = pending.get(scan);
                if policy.max_batch_tokens > 0
                    && live_tokens + cand.need_seq() > policy.max_batch_tokens
                {
                    // skip, don't stop: a smaller lower-ranked
                    // candidate may still fit this round
                    scan += 1;
                    continue;
                }
                // duplicate of an in-flight id: reject it (see the
                // seed loop) rather than shadow the live request
                if meta.contains_key(&cand.id)
                    || accepted.iter().any(|a| a.id == cand.id)
                {
                    let dup = pending.remove(scan);
                    if !send_failed(
                        &out,
                        &mut report,
                        worker,
                        &inflight,
                        dup,
                        "duplicate request id in flight".into(),
                        "bad_request",
                    ) {
                        break 'pool;
                    }
                    continue;
                }
                accepted_inputs.push(engine_input(cand));
                if !session.can_admit(&accepted_inputs) {
                    // tell paged-capacity blocking (transient: the
                    // candidate waits for retirements to free blocks;
                    // metered as blocked_on_capacity) apart from
                    // PERMANENT infeasibility — over max_seq, or a
                    // reservation bigger than the whole pool.  The
                    // permanent case can never admit no matter how
                    // long it waits, so fail it NOW instead of
                    // head-blocking the queue for a session lifetime.
                    if let Some(st) = session.kv_stats() {
                        let need =
                            cand.need_seq().div_ceil(st.block_size);
                        if cand.need_seq() > engine.max_seq()
                            || need > st.total_blocks
                        {
                            accepted_inputs.pop();
                            // message built before the pop ends the
                            // candidate borrow
                            let msg = format!(
                                "request needs {} sequence slots \
                                 ({need} kv blocks); the engine \
                                 serves at most max_seq {} with a \
                                 {}-block pool — it can never be \
                                 admitted",
                                cand.need_seq(),
                                engine.max_seq(),
                                st.total_blocks
                            );
                            let bad = pending.remove(scan);
                            if !send_failed(
                                &out,
                                &mut report,
                                worker,
                                &inflight,
                                bad,
                                msg,
                                "bad_request",
                            ) {
                                break 'pool;
                            }
                            continue;
                        }
                        // transient KV shortage: a higher-priority
                        // candidate may evict strictly-lower-priority
                        // live rows instead of waiting behind them
                        if !preempt_until_admittable(
                            session.as_mut(),
                            &meta,
                            cand.priority,
                            &accepted_inputs,
                            &mut report,
                        ) {
                            accepted_inputs.pop();
                            let free = session
                                .kv_stats()
                                .map_or(0, |s| s.free_blocks);
                            if free < need {
                                capacity_blocked = true;
                            }
                            scan += 1;
                            continue;
                        }
                        // fall through: the victims' blocks made room
                    } else {
                        // contiguous caches: bucket infeasibility —
                        // skip and let a smaller candidate try
                        accepted_inputs.pop();
                        scan += 1;
                        continue;
                    }
                }
                let cand = pending.remove(scan);
                live_tokens += cand.need_seq();
                accepted.push(cand);
            }
            // meter how long the queue head stays FULLY stalled on
            // capacity (window: first round that admitted nothing for
            // lack of free blocks -> first round that admitted
            // something or wasn't capacity-bound).  A round that
            // admits candidates before hitting the shortfall still
            // makes progress, so it closes the window.
            if capacity_blocked && accepted.is_empty() {
                blocked_since.get_or_insert_with(Instant::now);
            } else if let Some(t0) = blocked_since.take() {
                report.blocked_on_capacity += t0.elapsed();
            }
            if accepted.is_empty() {
                report.step_latency.record(step_cost);
                continue;
            }
            let t = Instant::now();
            match session.admit(&accepted_inputs) {
                Ok(()) => {
                    // admission prefill cost: with monolithic prefill
                    // it all lands in THIS iteration's latency; with
                    // chunked prefill admit() only allocates tables and
                    // the prompt cost spreads over later steps
                    let admit_cost = t.elapsed();
                    report.busy += admit_cost;
                    report.step_latency.record(step_cost + admit_cost);
                    report.admitted += accepted.len() as u64;
                    report.admitted_mid_session += accepted.len() as u64;
                    let pft = session.prefill_tokens();
                    report.admission_prefill_tokens +=
                        pft.saturating_sub(session_prefill);
                    session_prefill = pft;
                    if let Some(p) = session.prefix_stats() {
                        report.prefix_lookups +=
                            p.lookups - session_prefix.lookups;
                        report.prefix_hits += p.hits - session_prefix.hits;
                        report.prefix_tokens_reused +=
                            p.tokens_reused - session_prefix.tokens_reused;
                        session_prefix = p;
                    }
                    if let Some(st) = session.kv_stats() {
                        report.kv_peak_blocks_in_use = report
                            .kv_peak_blocks_in_use
                            .max(st.used_blocks() as u64);
                    }
                    for r in accepted {
                        meta.insert(
                            r.id,
                            RowMeta { req: r, first_token: None },
                        );
                    }
                }
                Err(e) => {
                    // admission failure kills the session (contract):
                    // fail the live rows AND the candidates
                    report.step_latency.record(step_cost + t.elapsed());
                    let (msg, code) = (e.to_string(), e.code());
                    for r in accepted
                        .into_iter()
                        .chain(meta.drain().map(|(_, m)| m.req))
                    {
                        if !send_failed(
                            &out,
                            &mut report,
                            worker,
                            &inflight,
                            r,
                            msg.clone(),
                            code,
                        ) {
                            break 'pool;
                        }
                    }
                    break;
                }
            }
        }
        if let Some(t0) = blocked_since.take() {
            report.blocked_on_capacity += t0.elapsed();
        }
        report.session_latency.record(t_session.elapsed());
    }

    let mut stats = backend.stats();
    stats.compile_secs -= compile_before;
    report.runtime_stats = stats;
    report
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::PreparedRequest;
    use crate::special;

    fn small_cfg(workers: usize) -> ServingConfig {
        let mut cfg = ServingConfig::default();
        cfg.workers = workers;
        cfg.row_threads = 1;
        cfg.gen.max_new_tokens = 4;
        cfg
    }

    fn request(id: u64, max_new: usize) -> PreparedRequest {
        PreparedRequest::new(
            id,
            vec![
                special::BOS,
                special::FIRST_WORD + (id as u32 % 40),
                special::SEP,
            ],
            max_new,
        )
    }

    fn batch_of(ids: &[u64]) -> Batch {
        Batch {
            requests: ids.iter().map(|&id| request(id, 4)).collect(),
            seq_bucket: 32,
        }
    }

    /// Collect the event stream on a side thread so workers never block
    /// on a full channel while the test is joining the pool.
    fn collector(
        rx: mpsc::Receiver<PoolEvent>,
    ) -> std::thread::JoinHandle<Vec<PoolEvent>> {
        std::thread::spawn(move || rx.iter().collect())
    }

    fn finished_ids(events: &[PoolEvent]) -> Vec<u64> {
        let mut ids: Vec<u64> = events
            .iter()
            .filter_map(|e| match e {
                PoolEvent::Finished { request, .. } => Some(request.id),
                _ => None,
            })
            .collect();
        ids.sort_unstable();
        ids
    }

    #[test]
    fn pool_processes_requests_and_reports() {
        let (out_tx, out_rx) = mpsc::sync_channel(64);
        let pool = InferencePool::start(&small_cfg(2), out_tx).unwrap();
        assert_eq!(pool.workers(), 2);
        let input = pool.input();
        let events = collector(out_rx);
        for i in 0..4u64 {
            input.send(batch_of(&[i * 2, i * 2 + 1])).unwrap();
        }
        drop(input);
        let report = pool.join();
        let events = events.join().unwrap();
        assert_eq!(finished_ids(&events), (0..8).collect::<Vec<u64>>());
        // ttft is recorded for exactly the requests that emitted tokens
        let with_tokens = events
            .iter()
            .filter(|e| {
                matches!(e, PoolEvent::Finished { generated, .. }
                    if !generated.is_empty())
            })
            .count() as u64;
        for ev in &events {
            if let PoolEvent::Finished { steps, .. } = ev {
                assert!(*steps > 0);
            }
        }
        assert_eq!(report.workers.len(), 2);
        assert_eq!(report.throughput().items(), 8);
        assert!(report.session_latency().count() > 0);
        assert!(report.steps_per_retire() >= 1.0);
        assert_eq!(report.ttft().count(), with_tokens);
        assert!(report.runtime_stats().executions > 0);
    }

    #[test]
    fn token_events_stream_before_terminal() {
        let (out_tx, out_rx) = mpsc::sync_channel(64);
        let pool = InferencePool::start(&small_cfg(1), out_tx).unwrap();
        let input = pool.input();
        let events = collector(out_rx);
        input.send(batch_of(&[7])).unwrap();
        drop(input);
        pool.join();
        let events = events.join().unwrap();
        let mut streamed: Vec<u32> = Vec::new();
        let mut terminal: Option<Vec<u32>> = None;
        for ev in events {
            match ev {
                PoolEvent::Tokens { id, tokens, .. } => {
                    assert_eq!(id, 7);
                    assert!(
                        terminal.is_none(),
                        "tokens after the terminal event"
                    );
                    streamed.extend(tokens);
                }
                PoolEvent::Finished { generated, .. } => {
                    terminal = Some(generated)
                }
                PoolEvent::Failed { message, .. } => {
                    panic!("unexpected failure: {message}")
                }
            }
        }
        let generated = terminal.expect("no terminal event");
        assert_eq!(streamed, generated, "stream must equal the summary");
    }

    #[test]
    fn oversized_request_yields_typed_error_not_silence() {
        let (out_tx, out_rx) = mpsc::sync_channel(64);
        let pool = InferencePool::start(&small_cfg(1), out_tx).unwrap();
        let input = pool.input();
        let events = collector(out_rx);
        // no compiled bucket fits 10_000 generated tokens -> NoBucket
        let mut bad = batch_of(&[7]);
        bad.requests[0].max_new_tokens = 10_000;
        input.send(bad).unwrap();
        input.send(batch_of(&[8])).unwrap(); // pool keeps serving after
        drop(input);
        let report = pool.join();
        let events = events.join().unwrap();
        let failed: Vec<_> = events
            .iter()
            .filter_map(|e| match e {
                PoolEvent::Failed { request, message, code, .. } => {
                    Some((request.id, message.clone(), *code))
                }
                _ => None,
            })
            .collect();
        assert_eq!(failed.len(), 1);
        assert_eq!(failed[0].0, 7);
        // paged engines reject on max_seq, contiguous ones on buckets
        assert!(
            failed[0].1.contains("max_seq") || failed[0].1.contains("bucket"),
            "{}",
            failed[0].1
        );
        assert_eq!(failed[0].2, "bad_request");
        assert_eq!(finished_ids(&events), vec![8]);
        assert_eq!(report.workers[0].failed_requests, 1);
    }

    #[test]
    fn late_batch_is_admitted_into_running_session() {
        // THE continuous-batching assertion: a request that arrives
        // after a session started decoding joins it mid-flight.  The
        // worker seeds a session from exactly one queued batch, so the
        // second batch — already queued when the session starts — can
        // only be served by between-step admission.
        let mut cfg = small_cfg(1);
        cfg.gen.max_new_tokens = 24; // long decode: many step boundaries
        let (out_tx, out_rx) = mpsc::sync_channel(1024);
        let pool = InferencePool::start(&cfg, out_tx).unwrap();
        let input = pool.input();
        let events = collector(out_rx);
        let mut a = batch_of(&[1, 2]);
        for r in &mut a.requests {
            r.max_new_tokens = 24;
        }
        let mut b = batch_of(&[3]);
        b.requests[0].max_new_tokens = 24;
        input.send(a).unwrap();
        input.send(b).unwrap();
        drop(input);
        let report = pool.join();
        let events = events.join().unwrap();
        assert_eq!(finished_ids(&events), vec![1, 2, 3]);
        assert!(
            report.admitted_mid_session() >= 1,
            "late batch was not admitted into the running session"
        );
        assert_eq!(report.workers[0].sessions, 1, "one continuous session");
    }

    #[test]
    fn cache_pressure_queues_admissions_and_serves_everyone() {
        // Capacity-aware scheduling under a starved pool: 6 blocks of 4
        // slots hold ~2 requests (prompt 3 + budget 8 = 11 slots = 3
        // blocks each), so the remaining 8 queue on KV capacity and are
        // admitted as retirements free blocks.  Every request must
        // still reach exactly one terminal event.
        let mut cfg = small_cfg(1);
        cfg.gen.max_new_tokens = 8;
        cfg.kv.block_size = 4;
        cfg.kv.blocks = 6;
        let (out_tx, out_rx) = mpsc::sync_channel(1024);
        let pool = InferencePool::start(&cfg, out_tx).unwrap();
        let input = pool.input();
        let events = collector(out_rx);
        let ids: Vec<u64> = (0..10).collect();
        let mut b = batch_of(&ids);
        for r in &mut b.requests {
            r.max_new_tokens = 8;
        }
        input.send(b).unwrap();
        drop(input);
        let report = pool.join();
        let events = events.join().unwrap();
        assert_eq!(finished_ids(&events), ids, "requests lost under pressure");
        assert!(
            events.iter().all(|e| !matches!(e, PoolEvent::Failed { .. })),
            "cache pressure must queue, not fail"
        );
        let kv = report.kv_metrics();
        assert_eq!(kv.kv_total_blocks, 6);
        assert!(kv.kv_peak_blocks_in_use > 0);
        assert!(kv.kv_peak_blocks_in_use <= 6, "pool overcommitted");
        assert!(
            kv.admitted_mid_session >= 1,
            "a starved pool must admit later arrivals mid-session"
        );
        assert!(kv.admission_prefill_tokens > 0);
        // Finished events carry the occupancy snapshot for the wire
        assert!(events.iter().any(|e| matches!(
            e,
            PoolEvent::Finished { kv: Some(st), .. } if st.total_blocks == 6
        )));
    }

    #[test]
    fn static_mode_never_admits_mid_session() {
        let mut cfg = small_cfg(1);
        cfg.continuous = false;
        let (out_tx, out_rx) = mpsc::sync_channel(1024);
        let pool = InferencePool::start(&cfg, out_tx).unwrap();
        let input = pool.input();
        let events = collector(out_rx);
        input.send(batch_of(&[1, 2])).unwrap();
        input.send(batch_of(&[3])).unwrap();
        drop(input);
        let report = pool.join();
        let events = events.join().unwrap();
        assert_eq!(finished_ids(&events), vec![1, 2, 3]);
        assert_eq!(report.admitted_mid_session(), 0);
        assert_eq!(report.workers[0].sessions, 2, "static: one per batch");
    }

    #[test]
    fn precancelled_request_fails_with_cancelled_code() {
        let (out_tx, out_rx) = mpsc::sync_channel(64);
        let pool = InferencePool::start(&small_cfg(1), out_tx).unwrap();
        let input = pool.input();
        let events = collector(out_rx);
        let mut b = batch_of(&[5, 6]);
        let flag = Arc::new(std::sync::atomic::AtomicBool::new(true));
        b.requests[0].cancel = Some(flag);
        input.send(b).unwrap();
        drop(input);
        pool.join();
        let events = events.join().unwrap();
        let mut saw_cancel = false;
        for ev in &events {
            match ev {
                PoolEvent::Failed { request, code, .. } => {
                    assert_eq!(request.id, 5);
                    assert_eq!(*code, "cancelled");
                    saw_cancel = true;
                }
                PoolEvent::Tokens { id, .. } => {
                    assert_ne!(*id, 5, "cancelled request streamed tokens");
                }
                _ => {}
            }
        }
        assert!(saw_cancel, "no cancelled terminal event");
        assert_eq!(finished_ids(&events), vec![6], "6 still served");
    }

    #[test]
    fn expired_deadline_fails_with_deadline_code() {
        let (out_tx, out_rx) = mpsc::sync_channel(64);
        let pool = InferencePool::start(&small_cfg(1), out_tx).unwrap();
        let input = pool.input();
        let events = collector(out_rx);
        let mut b = batch_of(&[9]);
        b.requests[0].deadline = Some(Instant::now());
        input.send(b).unwrap();
        drop(input);
        pool.join();
        let events = events.join().unwrap();
        assert!(events.iter().any(|e| matches!(
            e,
            PoolEvent::Failed { request, code: "deadline", .. }
                if request.id == 9
        )));
    }

    /// Greedy reference stream: the request served alone in a roomy
    /// pool (rows are independent, so every scheduling interleaving
    /// must reproduce exactly this).
    fn solo_generated(id: u64, max_new: usize) -> Vec<u32> {
        let mut cfg = small_cfg(1);
        cfg.gen.max_new_tokens = max_new;
        let (out_tx, out_rx) = mpsc::sync_channel(4096);
        let pool = InferencePool::start(&cfg, out_tx).unwrap();
        let input = pool.input();
        let events = collector(out_rx);
        let mut b = batch_of(&[id]);
        b.requests[0].max_new_tokens = max_new;
        input.send(b).unwrap();
        drop(input);
        pool.join();
        let events = events.join().unwrap();
        events
            .into_iter()
            .find_map(|e| match e {
                PoolEvent::Finished { generated, .. } => Some(generated),
                _ => None,
            })
            .expect("solo run lost its request")
    }

    #[test]
    fn worker_panic_fails_inflight_requests_typed() {
        // A worker that panics mid-decode must not take its in-flight
        // requests down silently: join() catches the panic and emits a
        // typed engine_error terminal for each owned request.
        const HOOK: u64 = 0xDEAD_BEEF_u64;
        let (out_tx, out_rx) = mpsc::sync_channel(64);
        let pool = InferencePool::start(&small_cfg(1), out_tx).unwrap();
        let input = pool.input();
        let events = collector(out_rx);
        PANIC_ON_REQUEST
            .store(HOOK, std::sync::atomic::Ordering::Relaxed);
        input.send(batch_of(&[HOOK])).unwrap();
        drop(input);
        let report = pool.join();
        PANIC_ON_REQUEST
            .store(u64::MAX, std::sync::atomic::Ordering::Relaxed);
        let events = events.join().unwrap();
        let failed: Vec<_> = events
            .iter()
            .filter_map(|e| match e {
                PoolEvent::Failed { request, code, .. } => {
                    Some((request.id, *code))
                }
                _ => None,
            })
            .collect();
        assert_eq!(failed, vec![(HOOK, "engine_error")]);
        assert!(finished_ids(&events).is_empty());
        assert_eq!(report.workers.len(), 1, "dead worker still reported");
        assert_eq!(report.workers[0].failed_requests, 1);
    }

    #[test]
    fn interactive_overtakes_queued_batch_head() {
        // One-row sessions: the queued Interactive request must be
        // served before the Batch request that arrived first.
        let mut cfg = small_cfg(1);
        cfg.batch.max_batch = 1;
        let (out_tx, out_rx) = mpsc::sync_channel(64);
        let pool = InferencePool::start(&cfg, out_tx).unwrap();
        let input = pool.input();
        let events = collector(out_rx);
        let mut b = batch_of(&[1, 2]);
        b.requests[0].priority = Priority::Batch;
        input.send(b).unwrap();
        drop(input);
        pool.join();
        let events = events.join().unwrap();
        let order: Vec<u64> = events
            .iter()
            .filter_map(|e| match e {
                PoolEvent::Finished { request, .. } => Some(request.id),
                _ => None,
            })
            .collect();
        assert_eq!(order, vec![2, 1], "interactive must run first");
    }

    #[test]
    fn mixed_priority_burst_exactly_one_terminal_each() {
        // Bursty overload on a starved pool with mixed priorities and
        // deadlines: every request still gets EXACTLY one terminal
        // event, and nothing fails (deadlines are generous).
        let mut cfg = small_cfg(1);
        cfg.gen.max_new_tokens = 6;
        cfg.kv.block_size = 4;
        cfg.kv.blocks = 8;
        let (out_tx, out_rx) = mpsc::sync_channel(4096);
        let pool = InferencePool::start(&cfg, out_tx).unwrap();
        let input = pool.input();
        let events = collector(out_rx);
        let ids: Vec<u64> = (0..24).collect();
        let mut b = batch_of(&ids);
        for (i, r) in b.requests.iter_mut().enumerate() {
            r.max_new_tokens = 6;
            if i % 3 == 0 {
                r.priority = Priority::Batch;
            }
            if i % 5 == 0 {
                r.deadline =
                    Some(Instant::now() + Duration::from_secs(3600));
            }
        }
        input.send(b).unwrap();
        drop(input);
        pool.join();
        let events = events.join().unwrap();
        let mut terminals: HashMap<u64, usize> = HashMap::new();
        for e in &events {
            let id = match e {
                PoolEvent::Finished { request, .. } => request.id,
                PoolEvent::Failed { request, .. } => request.id,
                PoolEvent::Tokens { .. } => continue,
            };
            *terminals.entry(id).or_insert(0) += 1;
        }
        assert_eq!(terminals.len(), 24, "requests lost: {terminals:?}");
        assert!(
            terminals.values().all(|&c| c == 1),
            "duplicate terminals: {terminals:?}"
        );
        assert!(
            events.iter().all(|e| !matches!(e, PoolEvent::Failed { .. })),
            "healthy overload must queue/preempt, never fail"
        );
    }

    #[test]
    fn interactive_preempts_batch_and_streams_are_identical() {
        // Two Batch-priority hogs reserve the whole block pool; an
        // Interactive probe arriving mid-decode cannot fit, so the
        // scheduler must evict a hog (Preempted -> requeue), admit the
        // probe, and resume the hog when blocks free up.  Greedy
        // streams must be bitwise-identical to uninterrupted solo runs
        // for every participant.
        let mut cfg = small_cfg(1);
        cfg.gen.max_new_tokens = 64;
        cfg.kv.block_size = 4;
        cfg.kv.blocks = 34; // 2 hogs x ceil((3+64)/4)=17 -> pool full
        let (out_tx, out_rx) = mpsc::sync_channel(4096);
        let pool = InferencePool::start(&cfg, out_tx).unwrap();
        let input = pool.input();
        let mut hogs = batch_of(&[1, 2]);
        for r in &mut hogs.requests {
            r.max_new_tokens = 64;
            r.priority = Priority::Batch;
        }
        input.send(hogs).unwrap();
        // wait until the hogs actually stream, so the probe can only
        // enter through between-step admission (and thus preemption)
        let mut events: Vec<PoolEvent> = Vec::new();
        while !events
            .iter()
            .any(|e| matches!(e, PoolEvent::Tokens { .. }))
        {
            events.push(out_rx.recv().expect("pool died before streaming"));
        }
        let mut probe = batch_of(&[3]);
        probe.requests[0].max_new_tokens = 8; // Interactive by default
        input.send(probe).unwrap();
        drop(input);
        let report = pool.join();
        events.extend(out_rx.try_iter());
        assert_eq!(finished_ids(&events), vec![1, 2, 3]);
        assert!(
            report.kv_metrics().preemptions >= 1,
            "full pool + interactive arrival must preempt"
        );
        let mut preempted_replies = 0u32;
        for ev in &events {
            if let PoolEvent::Finished { request, generated, .. } = ev {
                let max_new = if request.id == 3 { 8 } else { 64 };
                assert_eq!(
                    generated,
                    &solo_generated(request.id, max_new),
                    "request {} diverged across evict/resume",
                    request.id
                );
                // the reply carries the ORIGINAL request shape, not
                // the internal resume shape
                assert_eq!(request.prompt.len(), 3);
                assert_eq!(request.max_new_tokens, max_new);
                preempted_replies += request.preemptions;
            }
        }
        assert!(
            preempted_replies >= 1,
            "no Finished reply recorded its preemption count"
        );
        // the live stream (pre-eviction + post-resume) must equal the
        // stitched summary, in order, for every request
        for id in [1u64, 2, 3] {
            let streamed: Vec<u32> = events
                .iter()
                .filter_map(|e| match e {
                    PoolEvent::Tokens { id: i, tokens, .. } if *i == id => {
                        Some(tokens.clone())
                    }
                    _ => None,
                })
                .flatten()
                .collect();
            let generated = events
                .iter()
                .find_map(|e| match e {
                    PoolEvent::Finished { request, generated, .. }
                        if request.id == id =>
                    {
                        Some(generated.clone())
                    }
                    _ => None,
                })
                .unwrap();
            assert_eq!(streamed, generated, "stream mismatch for {id}");
        }
    }

    #[test]
    fn chunked_prefill_matches_monolithic_tokens() {
        // Chunk sizes that split the 22-token prompts unevenly must
        // all produce bitwise-identical greedy streams: a chunked
        // continuation attends over exactly the slots the monolithic
        // prefill would.
        let run = |chunk: usize| -> Vec<(u64, Vec<u32>)> {
            let mut cfg = small_cfg(1);
            cfg.gen.max_new_tokens = 6;
            cfg.gen.prefill_chunk = chunk;
            let (out_tx, out_rx) = mpsc::sync_channel(1024);
            let pool = InferencePool::start(&cfg, out_tx).unwrap();
            let input = pool.input();
            let events = collector(out_rx);
            let mut b = Batch { requests: Vec::new(), seq_bucket: 32 };
            for id in 0..4u64 {
                let mut prompt = vec![special::BOS];
                for k in 0..20u64 {
                    prompt.push(
                        special::FIRST_WORD + ((id * 7 + k) % 40) as u32,
                    );
                }
                prompt.push(special::SEP);
                b.requests.push(PreparedRequest::new(id, prompt, 6));
            }
            input.send(b).unwrap();
            drop(input);
            pool.join();
            let events = events.join().unwrap();
            let mut outs: Vec<(u64, Vec<u32>)> = events
                .into_iter()
                .filter_map(|e| match e {
                    PoolEvent::Finished { request, generated, .. } => {
                        Some((request.id, generated))
                    }
                    _ => None,
                })
                .collect();
            outs.sort_by_key(|(id, _)| *id);
            outs
        };
        let mono = run(0);
        assert_eq!(mono.len(), 4, "monolithic run lost requests");
        for chunk in [1usize, 4, 7, 64] {
            assert_eq!(run(chunk), mono, "chunk={chunk} diverged");
        }
    }

    /// Shared-prefix prompt: a fixed 19-word stem behind BOS (five
    /// full blocks at block_size 4), then a per-request tail word and
    /// SEP — divergence lands in the open partial block, so admissions
    /// after the first can adopt every full stem block.
    fn stem_prompt(id: u64) -> Vec<u32> {
        let mut p = vec![special::BOS];
        for k in 0..19u32 {
            p.push(special::FIRST_WORD + (k * 3) % 40);
        }
        p.push(special::FIRST_WORD + 20 + (id as u32 % 16));
        p.push(special::SEP);
        p
    }

    /// The request served alone with sharing disabled — the reference
    /// stream every sharing interleaving must reproduce bitwise.
    fn solo_noshare(prompt: Vec<u32>, max_new: usize) -> Vec<u32> {
        let mut cfg = small_cfg(1);
        cfg.gen.max_new_tokens = max_new;
        cfg.kv.prefix_share = false;
        let (out_tx, out_rx) = mpsc::sync_channel(4096);
        let pool = InferencePool::start(&cfg, out_tx).unwrap();
        let input = pool.input();
        let events = collector(out_rx);
        input
            .send(Batch {
                requests: vec![PreparedRequest::new(0, prompt, max_new)],
                seq_bucket: 32,
            })
            .unwrap();
        drop(input);
        pool.join();
        events
            .join()
            .unwrap()
            .into_iter()
            .find_map(|e| match e {
                PoolEvent::Finished { generated, .. } => Some(generated),
                _ => None,
            })
            .expect("solo run lost its request")
    }

    #[test]
    fn prefix_hits_compose_with_chunked_prefill() {
        // Composition with chunked prefill: a second wave whose
        // prompts share the stem with the already-indexed first wave
        // must hit the prefix cache whether admission prefill is
        // monolithic or chunked, and every stream must equal a solo
        // no-sharing run.
        let run = |chunk: usize| -> Vec<(u64, Vec<u32>)> {
            let mut cfg = small_cfg(1);
            cfg.gen.max_new_tokens = 24;
            cfg.gen.prefill_chunk = chunk;
            cfg.kv.block_size = 4;
            let (out_tx, out_rx) = mpsc::sync_channel(4096);
            let pool = InferencePool::start(&cfg, out_tx).unwrap();
            let input = pool.input();
            let mut wave1 = Batch { requests: Vec::new(), seq_bucket: 32 };
            for id in 0..2u64 {
                wave1
                    .requests
                    .push(PreparedRequest::new(id, stem_prompt(id), 24));
            }
            input.send(wave1).unwrap();
            // wait for a token: the emitting row finished its (maybe
            // chunked) prefill, so its stem is in the prefix index
            let mut events: Vec<PoolEvent> = Vec::new();
            while !events
                .iter()
                .any(|e| matches!(e, PoolEvent::Tokens { .. }))
            {
                events
                    .push(out_rx.recv().expect("pool died before streaming"));
            }
            let mut wave2 = Batch { requests: Vec::new(), seq_bucket: 32 };
            for id in 2..4u64 {
                wave2
                    .requests
                    .push(PreparedRequest::new(id, stem_prompt(id), 6));
            }
            input.send(wave2).unwrap();
            drop(input);
            let report = pool.join();
            events.extend(out_rx.try_iter());
            assert_eq!(
                finished_ids(&events),
                vec![0, 1, 2, 3],
                "chunk={chunk}: requests lost"
            );
            let kv = report.kv_metrics();
            assert!(
                kv.admitted_mid_session >= 1,
                "chunk={chunk}: second wave missed the running session"
            );
            assert!(
                kv.prefix_hits >= 1,
                "chunk={chunk}: shared-stem wave produced no prefix hit"
            );
            assert!(
                kv.prefix_tokens_reused >= 4,
                "chunk={chunk}: a hit must reuse at least a full block"
            );
            assert!(kv.prefix_hit_rate() > 0.0);
            let mut outs: Vec<(u64, Vec<u32>)> = events
                .into_iter()
                .filter_map(|e| match e {
                    PoolEvent::Finished { request, generated, .. } => {
                        Some((request.id, generated))
                    }
                    _ => None,
                })
                .collect();
            outs.sort_by_key(|(id, _)| *id);
            outs
        };
        let solos: Vec<(u64, Vec<u32>)> = (0..4u64)
            .map(|id| {
                let max_new = if id < 2 { 24 } else { 6 };
                (id, solo_noshare(stem_prompt(id), max_new))
            })
            .collect();
        for chunk in [0usize, 1, 5] {
            assert_eq!(
                run(chunk),
                solos,
                "chunk={chunk}: sharing changed a stream"
            );
        }
    }

    #[test]
    fn prefix_hits_compose_with_preemption_resume() {
        // Composition with preemption: an Interactive probe that
        // shares its stem with two pool-filling Batch hogs adopts
        // their indexed prefix blocks AND still forces a preemption
        // for its fresh tail blocks; every stream — the evicted and
        // resumed hog included — must equal a solo no-sharing run.
        let mut cfg = small_cfg(1);
        cfg.gen.max_new_tokens = 64;
        cfg.kv.block_size = 4;
        cfg.kv.blocks = 44; // 2 hogs x ceil((22+64)/4)=22 -> pool full
        let (out_tx, out_rx) = mpsc::sync_channel(4096);
        let pool = InferencePool::start(&cfg, out_tx).unwrap();
        let input = pool.input();
        let mut hogs = Batch { requests: Vec::new(), seq_bucket: 32 };
        for id in 1..3u64 {
            let mut r = PreparedRequest::new(id, stem_prompt(id), 64);
            r.priority = Priority::Batch;
            hogs.requests.push(r);
        }
        input.send(hogs).unwrap();
        // wait until the hogs stream, so the probe can only enter
        // through between-step admission (and thus preemption)
        let mut events: Vec<PoolEvent> = Vec::new();
        while !events
            .iter()
            .any(|e| matches!(e, PoolEvent::Tokens { .. }))
        {
            events.push(out_rx.recv().expect("pool died before streaming"));
        }
        let probe = Batch {
            requests: vec![PreparedRequest::new(3, stem_prompt(3), 8)],
            seq_bucket: 32,
        };
        input.send(probe).unwrap();
        drop(input);
        let report = pool.join();
        events.extend(out_rx.try_iter());
        assert_eq!(finished_ids(&events), vec![1, 2, 3]);
        let kv = report.kv_metrics();
        assert!(
            kv.preemptions >= 1,
            "full pool + interactive arrival must preempt"
        );
        assert!(
            kv.prefix_hits >= 1,
            "probe shares the stem: it must hit the prefix index"
        );
        assert!(kv.prefix_tokens_reused >= 4);
        for ev in &events {
            if let PoolEvent::Finished { request, generated, .. } = ev {
                let max_new = if request.id == 3 { 8 } else { 64 };
                assert_eq!(
                    generated,
                    &solo_noshare(stem_prompt(request.id), max_new),
                    "request {} diverged across share/evict/resume",
                    request.id
                );
            }
        }
    }

    #[test]
    fn speculation_composes_with_prefix_sharing() {
        // Speculative decode under prefix sharing: a second wave
        // adopts the first wave's indexed stem blocks while every row
        // drafts + verifies.  Streams must equal solo no-sharing
        // no-speculation runs, and drafts must ACTUALLY be accepted —
        // a vacuous pass would hide a broken drafter.
        let mut cfg = small_cfg(1);
        cfg.gen.max_new_tokens = 32;
        cfg.gen.speculate = 4;
        cfg.kv.block_size = 4;
        let (out_tx, out_rx) = mpsc::sync_channel(4096);
        let pool = InferencePool::start(&cfg, out_tx).unwrap();
        let input = pool.input();
        let mut wave1 = Batch { requests: Vec::new(), seq_bucket: 32 };
        for id in 0..2u64 {
            wave1
                .requests
                .push(PreparedRequest::new(id, stem_prompt(id), 32));
        }
        input.send(wave1).unwrap();
        // wait for a token so wave 2 can only hit the prefix index of
        // a running session (the composition under test)
        let mut events: Vec<PoolEvent> = Vec::new();
        while !events
            .iter()
            .any(|e| matches!(e, PoolEvent::Tokens { .. }))
        {
            events.push(out_rx.recv().expect("pool died before streaming"));
        }
        let mut wave2 = Batch { requests: Vec::new(), seq_bucket: 32 };
        for id in 2..4u64 {
            wave2
                .requests
                .push(PreparedRequest::new(id, stem_prompt(id), 8));
        }
        input.send(wave2).unwrap();
        drop(input);
        let report = pool.join();
        events.extend(out_rx.try_iter());
        assert_eq!(finished_ids(&events), vec![0, 1, 2, 3]);
        assert!(
            report.kv_metrics().prefix_hits >= 1,
            "shared-stem wave produced no prefix hit"
        );
        let spec = report.spec_metrics();
        assert!(spec.drafted > 0, "no drafts proposed (vacuous test)");
        assert!(spec.accepted > 0, "no drafts accepted (vacuous test)");
        assert!(
            events.iter().any(|e| matches!(
                e,
                PoolEvent::Finished { spec: Some(s), .. } if s.drafted > 0
            )),
            "Finished replies must carry the session's spec counters"
        );
        for ev in &events {
            if let PoolEvent::Finished { request, generated, .. } = ev {
                let max_new = if request.id < 2 { 32 } else { 8 };
                assert_eq!(
                    generated,
                    &solo_noshare(stem_prompt(request.id), max_new),
                    "request {} diverged under speculation x sharing",
                    request.id
                );
            }
        }
    }

    #[test]
    fn speculation_composes_with_preemption_resume() {
        // An Interactive probe preempts a speculating Batch hog; the
        // evicted hog resumes via a fresh admission prefill (its
        // generated tokens folded into the prompt — MORE drafter
        // context) and keeps speculating.  Every stream must equal a
        // solo no-speculation run, with real acceptance along the way.
        let mut cfg = small_cfg(1);
        cfg.gen.max_new_tokens = 64;
        cfg.gen.speculate = 4;
        cfg.kv.block_size = 4;
        cfg.kv.blocks = 44; // 2 hogs x ceil((22+64)/4)=22 -> pool full
        cfg.kv.prefix_share = false; // isolate the preemption axis
        let (out_tx, out_rx) = mpsc::sync_channel(4096);
        let pool = InferencePool::start(&cfg, out_tx).unwrap();
        let input = pool.input();
        let mut hogs = Batch { requests: Vec::new(), seq_bucket: 32 };
        for id in 1..3u64 {
            let mut r = PreparedRequest::new(id, stem_prompt(id), 64);
            r.priority = Priority::Batch;
            hogs.requests.push(r);
        }
        input.send(hogs).unwrap();
        // wait until the hogs stream, so the probe can only enter
        // through between-step admission (and thus preemption)
        let mut events: Vec<PoolEvent> = Vec::new();
        while !events
            .iter()
            .any(|e| matches!(e, PoolEvent::Tokens { .. }))
        {
            events.push(out_rx.recv().expect("pool died before streaming"));
        }
        let probe = Batch {
            requests: vec![PreparedRequest::new(3, stem_prompt(3), 8)],
            seq_bucket: 32,
        };
        input.send(probe).unwrap();
        drop(input);
        let report = pool.join();
        events.extend(out_rx.try_iter());
        assert_eq!(finished_ids(&events), vec![1, 2, 3]);
        assert!(
            report.kv_metrics().preemptions >= 1,
            "full pool + interactive arrival must preempt"
        );
        let spec = report.spec_metrics();
        assert!(spec.accepted > 0, "no drafts accepted (vacuous test)");
        assert_eq!(
            spec.accepted, spec.dispatches_saved,
            "every accepted draft is exactly one saved dispatch"
        );
        for ev in &events {
            if let PoolEvent::Finished { request, generated, .. } = ev {
                let max_new = if request.id == 3 { 8 } else { 64 };
                assert_eq!(
                    generated,
                    &solo_noshare(stem_prompt(request.id), max_new),
                    "request {} diverged under speculation x preemption",
                    request.id
                );
            }
        }
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn startup_failure_is_typed() {
        let mut cfg = small_cfg(2);
        cfg.backend = crate::config::BackendKind::Pjrt; // not built in
        let (out_tx, _out_rx) = mpsc::sync_channel(1);
        let err = InferencePool::start(&cfg, out_tx);
        assert!(err.is_err(), "pjrt without the feature must fail fast");
    }
}
