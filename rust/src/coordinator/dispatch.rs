//! The multi-worker inference pool — the paper's §3.3 "multi-process
//! parallel processing" scaled past one model process.
//!
//! [`InferencePool::start`] spawns `cfg.workers` OS threads.  Each
//! worker constructs **its own backend + engine** inside its thread
//! (per-worker weights and stats — the EnergonAI executor-pool shape)
//! plus a sampler seeded from `derive_seed(seed, worker)`, then
//! competes for batches on a shared queue.  Results — or typed errors —
//! flow to a single output channel, so downstream stages never observe
//! a silent drop: a failing batch yields `PoolOutput { generated:
//! Err(..) }` for its requests instead of a hung reply channel.
//!
//! With `workers == 1` the pool degenerates to the pre-pool pipeline:
//! one engine consumes batches in arrival order, producing
//! token-identical output (greedy decoding is deterministic and
//! per-request results are independent of batch placement).  Pooled
//! GREEDY runs stay deterministic for any worker count; pooled top-k is
//! reproducible per worker stream but batch→worker assignment is a
//! queue race, so run-to-run token sets may differ.
//!
//! Shutdown: the pool input disconnects when every
//! [`InferencePool::input`] clone AND the pool's own handle are
//! dropped; workers then drain, emit their [`WorkerReport`], and exit.
//! [`InferencePool::join`] merges the per-worker `Histogram` /
//! `Throughput` / `RuntimeStats` into one [`PoolReport`].

use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

use super::batcher::Batch;
use super::run_batch;
use crate::config::ServingConfig;
use crate::engine::{build as build_engine, sampler_for_worker};
use crate::metrics::{Histogram, Throughput};
use crate::runtime::{backend_for, Backend, RuntimeStats};
use crate::{Error, Result};

/// One processed batch leaving the pool.
pub struct PoolOutput {
    pub batch: Batch,
    /// Generated ids per request (batch order), or the batch's failure.
    pub generated: std::result::Result<Vec<Vec<u32>>, Error>,
    /// Which worker ran it (0-based).
    pub worker: usize,
    /// Inference wall time for this batch on that worker.
    pub elapsed: Duration,
}

/// What one worker did over its lifetime.
pub struct WorkerReport {
    pub worker: usize,
    /// Busy wall time inside `run_batch`.
    pub busy: Duration,
    pub batches: u64,
    /// Failed batches (their requests got error replies, not drops).
    pub failed_batches: u64,
    /// Per-batch inference latency on this worker.
    pub batch_latency: Histogram,
    /// Requests + generated tokens completed by this worker.
    pub throughput: Throughput,
    /// This worker's backend counters, with startup compilation that
    /// happened before the ready gate subtracted out.
    pub runtime_stats: RuntimeStats,
}

/// Per-worker reports plus their merged view.
pub struct PoolReport {
    pub workers: Vec<WorkerReport>,
}

impl PoolReport {
    /// Total busy time across workers (can exceed wall time — that is
    /// the point of the pool).
    pub fn busy(&self) -> Duration {
        self.workers.iter().map(|w| w.busy).sum()
    }

    /// Per-batch inference latency merged across workers.
    pub fn batch_latency(&self) -> Histogram {
        let mut h = Histogram::new();
        for w in &self.workers {
            h.merge(&w.batch_latency);
        }
        h
    }

    /// Items/tokens completed, merged across workers.
    pub fn throughput(&self) -> Throughput {
        let mut t = Throughput::new();
        for w in &self.workers {
            t.merge(&w.throughput);
        }
        t
    }

    /// Backend counters merged across the per-worker backends.
    pub fn runtime_stats(&self) -> RuntimeStats {
        let mut s = RuntimeStats::default();
        for w in &self.workers {
            s.merge(&w.runtime_stats);
        }
        s
    }
}

/// A pool of inference workers consuming [`Batch`]es from a shared
/// queue (see module docs).
pub struct InferencePool {
    input: mpsc::SyncSender<Batch>,
    handles: Vec<std::thread::JoinHandle<WorkerReport>>,
}

impl InferencePool {
    /// Spawn `cfg.workers` workers, each standing up its own backend +
    /// engine, and block until every worker is ready (startup
    /// compilation done) or return the first startup error.  `out`
    /// receives one [`PoolOutput`] per consumed batch.
    pub fn start(
        cfg: &ServingConfig,
        out: mpsc::SyncSender<PoolOutput>,
    ) -> Result<Self> {
        cfg.validate()?;
        let n = cfg.workers;
        // input queue sized so the batcher can run ahead of slow workers
        let (input, rx) = mpsc::sync_channel::<Batch>(cfg.stage_queue.max(n));
        let rx = Arc::new(Mutex::new(rx));
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();

        let mut handles = Vec::with_capacity(n);
        for worker in 0..n {
            let cfg = cfg.clone();
            let rx = rx.clone();
            let out = out.clone();
            let ready_tx = ready_tx.clone();
            let handle = std::thread::Builder::new()
                .name(format!("inference-{worker}"))
                .spawn(move || worker_main(worker, cfg, rx, out, ready_tx))
                .expect("spawn inference worker");
            handles.push(handle);
        }
        drop(out);
        drop(ready_tx);

        // Ready gate: fail fast (typed) if any worker cannot stand up
        // its backend/engine, instead of leaving clients to hang.
        let mut startup_err = None;
        for _ in 0..n {
            match ready_rx.recv() {
                Ok(Ok(())) => {}
                Ok(Err(e)) => {
                    if startup_err.is_none() {
                        startup_err = Some(e);
                    }
                }
                Err(_) => {
                    if startup_err.is_none() {
                        startup_err =
                            Some(Error::Shutdown("worker died at startup"));
                    }
                }
            }
        }
        if let Some(e) = startup_err {
            // unblock and reap the workers that did start
            drop(input);
            for h in handles {
                let _ = h.join();
            }
            return Err(e);
        }
        Ok(Self { input, handles })
    }

    /// A clonable submission handle.  The pool drains and shuts down
    /// once every clone AND the pool itself are dropped/joined.
    pub fn input(&self) -> mpsc::SyncSender<Batch> {
        self.input.clone()
    }

    /// Number of workers.
    pub fn workers(&self) -> usize {
        self.handles.len()
    }

    /// Close the pool's own input handle, wait for the workers to
    /// drain, and merge their reports.
    pub fn join(self) -> PoolReport {
        let Self { input, handles } = self;
        drop(input);
        let mut workers: Vec<WorkerReport> = handles
            .into_iter()
            .map(|h| h.join().expect("inference worker panicked"))
            .collect();
        workers.sort_by_key(|w| w.worker);
        PoolReport { workers }
    }
}

fn worker_main(
    worker: usize,
    cfg: ServingConfig,
    rx: Arc<Mutex<mpsc::Receiver<Batch>>>,
    out: mpsc::SyncSender<PoolOutput>,
    ready_tx: mpsc::Sender<Result<()>>,
) -> WorkerReport {
    let mut report = WorkerReport {
        worker,
        busy: Duration::ZERO,
        batches: 0,
        failed_batches: 0,
        batch_latency: Histogram::new(),
        throughput: Throughput::new(),
        runtime_stats: RuntimeStats::default(),
    };

    // Per-worker backend + engine, constructed on this thread.
    let setup = backend_for(&cfg).and_then(|backend| {
        build_engine(cfg.engine, backend.clone(), cfg.gen)
            .map(|engine| (backend, engine))
    });
    let (backend, engine) = match setup {
        Ok(pair) => pair,
        Err(e) => {
            let _ = ready_tx.send(Err(e));
            return report;
        }
    };
    if cfg.precompile {
        if let Err(e) = crate::engine::precompile(cfg.engine, backend.as_ref())
        {
            let _ = ready_tx.send(Err(e));
            return report;
        }
    }
    let _ = ready_tx.send(Ok(()));
    // compilation before the ready gate is startup cost, not steady state
    let compile_before = backend.stats().compile_secs;

    let mut sampler = sampler_for_worker(cfg.sampling, worker as u64);
    loop {
        // hold the queue lock only for the pop, never during inference
        let batch = match rx.lock().unwrap().recv() {
            Ok(b) => b,
            Err(_) => break, // all senders gone: drain complete
        };
        let t = Instant::now();
        let result = run_batch(engine.as_ref(), &mut sampler, &batch);
        let elapsed = t.elapsed();
        report.busy += elapsed;
        report.batches += 1;
        report.batch_latency.record(elapsed);
        let generated = match result {
            Ok(outs) => {
                let generated: Vec<Vec<u32>> =
                    outs.into_iter().map(|(_, g)| g).collect();
                let tokens: u64 =
                    generated.iter().map(|g| g.len() as u64).sum();
                report.throughput.record(batch.len() as u64, tokens);
                Ok(generated)
            }
            Err(e) => {
                report.failed_batches += 1;
                Err(e)
            }
        };
        if out.send(PoolOutput { batch, generated, worker, elapsed }).is_err()
        {
            break; // downstream gone: stop consuming
        }
    }
    let mut stats = backend.stats();
    stats.compile_secs -= compile_before;
    report.runtime_stats = stats;
    report
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::PreparedRequest;
    use crate::special;

    fn small_cfg(workers: usize) -> ServingConfig {
        let mut cfg = ServingConfig::default();
        cfg.workers = workers;
        cfg.row_threads = 1;
        cfg.gen.max_new_tokens = 4;
        cfg
    }

    fn batch_of(ids: &[u64]) -> Batch {
        Batch {
            requests: ids
                .iter()
                .map(|&id| PreparedRequest {
                    id,
                    prompt: vec![
                        special::BOS,
                        special::FIRST_WORD + (id as u32 % 40),
                        special::SEP,
                    ],
                    max_new_tokens: 4,
                    reference_summary: None,
                    enqueued: std::time::Instant::now(),
                })
                .collect(),
            seq_bucket: 32,
        }
    }

    #[test]
    fn pool_processes_batches_and_reports() {
        let (out_tx, out_rx) = mpsc::sync_channel(16);
        let pool = InferencePool::start(&small_cfg(2), out_tx).unwrap();
        assert_eq!(pool.workers(), 2);
        let input = pool.input();
        for i in 0..4u64 {
            input.send(batch_of(&[i * 2, i * 2 + 1])).unwrap();
        }
        drop(input);
        let report = pool.join();
        let outs: Vec<PoolOutput> = out_rx.iter().collect();
        assert_eq!(outs.len(), 4);
        for o in &outs {
            let gen = o.generated.as_ref().expect("batch should succeed");
            assert_eq!(gen.len(), o.batch.len());
        }
        assert_eq!(report.workers.len(), 2);
        assert_eq!(
            report.workers.iter().map(|w| w.batches).sum::<u64>(),
            4
        );
        assert_eq!(report.throughput().items(), 8);
        assert_eq!(report.batch_latency().count(), 4);
        assert!(report.runtime_stats().executions > 0);
    }

    #[test]
    fn oversized_batch_yields_typed_error_not_silence() {
        let (out_tx, out_rx) = mpsc::sync_channel(4);
        let pool = InferencePool::start(&small_cfg(1), out_tx).unwrap();
        let input = pool.input();
        // no compiled bucket fits 10_000 generated tokens -> NoBucket
        let mut bad = batch_of(&[7]);
        bad.requests[0].max_new_tokens = 10_000;
        input.send(bad).unwrap();
        input.send(batch_of(&[8])).unwrap(); // pool keeps serving after
        drop(input);
        let report = pool.join();
        let outs: Vec<PoolOutput> = out_rx.iter().collect();
        assert_eq!(outs.len(), 2);
        assert!(outs.iter().any(|o| o.generated.is_err()));
        assert!(outs.iter().any(|o| o.generated.is_ok()));
        assert_eq!(report.workers[0].failed_batches, 1);
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn startup_failure_is_typed() {
        let mut cfg = small_cfg(2);
        cfg.backend = crate::config::BackendKind::Pjrt; // not built in
        let (out_tx, _out_rx) = mpsc::sync_channel(1);
        let err = InferencePool::start(&cfg, out_tx);
        assert!(err.is_err(), "pjrt without the feature must fail fast");
    }
}
