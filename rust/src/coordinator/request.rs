//! Request/response types as they move through the pipeline stages.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::{Error, Result};

/// Scheduling class of a request.  Ordered: `Batch < Interactive`, so
/// `Ord` compares urgency directly.
///
/// The pending queue orders by (priority desc, deadline asc, arrival),
/// and under KV-capacity pressure an `Interactive` arrival may preempt
/// a live `Batch` row (strictly-lower priority only — equal-priority
/// rows never preempt each other, so all-default workloads behave
/// exactly as before this field existed).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Default)]
pub enum Priority {
    /// Throughput-oriented background work; first to be preempted.
    Batch,
    /// Latency-sensitive traffic (the default).
    #[default]
    Interactive,
}

impl Priority {
    pub fn label(self) -> &'static str {
        match self {
            Priority::Batch => "batch",
            Priority::Interactive => "interactive",
        }
    }

    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "batch" => Ok(Priority::Batch),
            "interactive" => Ok(Priority::Interactive),
            _ => Err(Error::Other(format!(
                "unknown priority '{s}' (interactive|batch)"
            ))),
        }
    }
}

/// A request after preprocessing (tokenization) — what the batcher and
/// engine operate on.
#[derive(Debug, Clone)]
pub struct PreparedRequest {
    pub id: u64,
    /// `[BOS] doc… [SEP]`.  After a preemption this is the ORIGINAL
    /// prompt plus every token generated before eviction, so resuming
    /// is one admission prefill away and greedy continuations are
    /// bitwise-identical to the uninterrupted stream.
    pub prompt: Vec<u32>,
    pub max_new_tokens: usize,
    /// Ground-truth summary ids for quality scoring (synthetic workloads).
    pub reference_summary: Option<Vec<u32>>,
    /// When the request entered the system (latency measurement).
    pub enqueued: Instant,
    /// Absolute wall-clock deadline; the continuous batcher retires the
    /// request with a `deadline` error at the first step boundary past
    /// it.  None = no deadline (offline workloads).
    pub deadline: Option<Instant>,
    /// Cooperative cancellation flag, shared with the client's
    /// [`crate::server::RequestStream`].  Clones share the flag.
    pub cancel: Option<Arc<AtomicBool>>,
    /// Scheduling class (Interactive by default).
    pub priority: Priority,
    /// Tokens generated before the request was last preempted — a
    /// suffix of `prompt`.  The dispatcher stitches these ahead of the
    /// post-resume generation when the request finally finishes, so
    /// the client-visible stream is complete.  Empty for requests that
    /// were never preempted.
    pub preempted_generated: Vec<u32>,
    /// How many times this request has been preempted so far.
    pub preemptions: u32,
    /// True TTFT anchor across preemptions: when the request streamed
    /// its first token before an eviction, the original emission time
    /// survives the requeue here.
    pub first_emit: Option<Instant>,
}

impl PreparedRequest {
    /// A prepared request with no deadline/cancellation attached (the
    /// offline-workload shape; streaming fills the extra fields in).
    pub fn new(id: u64, prompt: Vec<u32>, max_new_tokens: usize) -> Self {
        Self {
            id,
            prompt,
            max_new_tokens,
            reference_summary: None,
            enqueued: Instant::now(),
            deadline: None,
            cancel: None,
            priority: Priority::default(),
            preempted_generated: Vec::new(),
            preemptions: 0,
            first_emit: None,
        }
    }

    /// Sequence capacity this request needs (prompt + generation).
    pub fn need_seq(&self) -> usize {
        self.prompt.len() + self.max_new_tokens
    }

    /// Has the client cancelled this request?
    pub fn cancelled(&self) -> bool {
        self.cancel
            .as_ref()
            .map(|c| c.load(Ordering::Relaxed))
            .unwrap_or(false)
    }

    /// Is `now` past the request's deadline?
    pub fn expired(&self, now: Instant) -> bool {
        self.deadline.map(|d| now >= d).unwrap_or(false)
    }
}

/// Wall-clock spent per pipeline stage for one batch (Fig 4 data).
#[derive(Debug, Clone, Copy, Default)]
pub struct StageTimes {
    pub preprocess: Duration,
    pub inference: Duration,
    pub postprocess: Duration,
}

/// The finished response.
#[derive(Debug, Clone)]
pub struct ServingResponse {
    pub id: u64,
    /// Generated summary token ids (EOS-trimmed).
    pub summary_ids: Vec<u32>,
    /// Detokenized summary text.
    pub summary_text: String,
    /// End-to-end latency (enqueue -> postprocess complete).
    pub latency: Duration,
    /// Time-to-first-token: enqueue -> first streamed token (None when
    /// the request failed before emitting anything).
    pub ttft: Option<Duration>,
    /// Decode-session iterations spent while this request was live
    /// (the steps-per-retire metric).
    pub steps: usize,
    /// Positional token accuracy vs. the reference summary, if known.
    pub accuracy: Option<f64>,
    /// Inference failure, if the request errored anywhere in the stack.
    /// Failed requests still get a reply (never a silent drop), with
    /// empty `summary_ids`/`summary_text`.
    pub error: Option<String>,
    /// Structured error code (`bad_request` | `overloaded` |
    /// `engine_error` | `cancelled` | `deadline`) when `error` is set.
    pub code: Option<&'static str>,
    /// Storage precision that produced this response (`"fp32"` /
    /// `"fp16"`), stamped by the executor on SUCCESSFUL replies and
    /// echoed on the wire so clients can tell reduced-precision output
    /// apart.  None on every failed reply (boundary rejections and
    /// mid-decode failures alike) — error events carry a `code`, not a
    /// precision claim.
    pub dtype: Option<&'static str>,
    /// Paged-KV pool occupancy `(blocks_in_use, total_blocks)`
    /// observed as the request retired — the per-reply cache-pressure
    /// signal, echoed on the wire (`kv_blocks_in_use` /
    /// `kv_blocks_total`).  None on contiguous caches and on failures.
    pub kv_blocks: Option<(u64, u64)>,
    /// Times the request was preempted (evicted + resumed) on its way
    /// to this reply — the per-request QoS cost of the SLO scheduler,
    /// echoed on the wire.
    pub preemptions: u32,
    /// Prefix-cache counters `(hits, tokens_reused)` of the session
    /// that retired this request, echoed on the wire (`prefix_hits` /
    /// `prefix_tokens_reused`).  None when sharing is off, the cache
    /// discipline is contiguous, or the request failed.
    pub prefix: Option<(u64, u64)>,
    /// Runtime vocab pruning `(kept_vocab, full_vocab)` the serving
    /// stack executed with — the kept-set size of the dense embedding
    /// the engine decoded over, and the original vocabulary the
    /// tokenizer (and this reply's `summary_ids`) speak.  Echoed on
    /// the wire (`pruned_vocab` / `full_vocab`); None when pruning is
    /// off or the request failed.
    pub pruned_vocab: Option<(u64, u64)>,
    /// Draft tokens the speculative decoder verified-and-accepted on
    /// the way to this reply — each one is a decode dispatch the engine
    /// did not pay for.  Echoed on the wire (`spec_accepted`); None
    /// when speculation is off (`--speculate 0`) or the request failed,
    /// so clients can tell "off" apart from "on but nothing accepted".
    pub spec_accepted: Option<u64>,
}

impl ServingResponse {
    /// The reply for a request that failed in the serving stack: empty
    /// summary, the failure message + structured code attached.
    pub fn failed(
        id: u64,
        latency: Duration,
        message: String,
        code: &'static str,
    ) -> Self {
        Self {
            id,
            summary_ids: Vec::new(),
            summary_text: String::new(),
            latency,
            ttft: None,
            steps: 0,
            accuracy: None,
            error: Some(message),
            code: Some(code),
            dtype: None,
            kv_blocks: None,
            preemptions: 0,
            prefix: None,
            pruned_vocab: None,
            spec_accepted: None,
        }
    }
}

/// Positional token accuracy: fraction of reference positions the
/// generation got right (the quality guard for fp16/pruning — §4
/// "maintaining high levels of performance").
pub fn summary_accuracy(generated: &[u32], reference: &[u32]) -> f64 {
    if reference.is_empty() {
        return if generated.is_empty() { 1.0 } else { 0.0 };
    }
    let hits = generated
        .iter()
        .zip(reference)
        .filter(|(g, r)| g == r)
        .count();
    hits as f64 / reference.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accuracy_perfect_and_partial() {
        assert_eq!(summary_accuracy(&[1, 2, 3], &[1, 2, 3]), 1.0);
        assert_eq!(summary_accuracy(&[1, 9, 3], &[1, 2, 3]), 2.0 / 3.0);
        assert_eq!(summary_accuracy(&[], &[1, 2]), 0.0);
        assert_eq!(summary_accuracy(&[], &[]), 1.0);
        // generation longer than reference: extra tokens don't add credit
        assert_eq!(summary_accuracy(&[1, 2, 3, 4], &[1, 2]), 1.0);
    }

    #[test]
    fn need_seq_adds_generation_budget() {
        let r = PreparedRequest::new(0, vec![1; 10], 6);
        assert_eq!(r.need_seq(), 16);
    }

    #[test]
    fn cancel_flag_is_shared_across_clones() {
        let mut r = PreparedRequest::new(1, vec![1], 4);
        assert!(!r.cancelled());
        let flag = Arc::new(AtomicBool::new(false));
        r.cancel = Some(flag.clone());
        let clone = r.clone();
        flag.store(true, Ordering::Relaxed);
        assert!(r.cancelled() && clone.cancelled());
    }

    #[test]
    fn priority_orders_parses_and_defaults() {
        assert!(Priority::Interactive > Priority::Batch);
        assert_eq!(Priority::default(), Priority::Interactive);
        assert_eq!(Priority::parse("batch").unwrap(), Priority::Batch);
        assert_eq!(
            Priority::parse("interactive").unwrap(),
            Priority::Interactive
        );
        assert!(Priority::parse("urgent").is_err());
        assert_eq!(Priority::Batch.label(), "batch");
        assert_eq!(Priority::Interactive.label(), "interactive");
        let r = PreparedRequest::new(1, vec![1], 4);
        assert_eq!(r.priority, Priority::Interactive);
        assert!(r.preempted_generated.is_empty());
        assert_eq!(r.preemptions, 0);
        assert!(r.first_emit.is_none());
    }

    #[test]
    fn deadline_expiry() {
        let mut r = PreparedRequest::new(1, vec![1], 4);
        let now = Instant::now();
        assert!(!r.expired(now));
        r.deadline = Some(now);
        assert!(r.expired(now));
        r.deadline = Some(now + Duration::from_secs(3600));
        assert!(!r.expired(now));
    }
}
