//! Request/response types as they move through the pipeline stages.

use std::time::{Duration, Instant};

/// A request after preprocessing (tokenization) — what the batcher and
/// engine operate on.
#[derive(Debug, Clone)]
pub struct PreparedRequest {
    pub id: u64,
    /// `[BOS] doc… [SEP]`.
    pub prompt: Vec<u32>,
    pub max_new_tokens: usize,
    /// Ground-truth summary ids for quality scoring (synthetic workloads).
    pub reference_summary: Option<Vec<u32>>,
    /// When the request entered the system (latency measurement).
    pub enqueued: Instant,
}

impl PreparedRequest {
    /// Sequence capacity this request needs (prompt + generation).
    pub fn need_seq(&self) -> usize {
        self.prompt.len() + self.max_new_tokens
    }
}

/// Wall-clock spent per pipeline stage for one batch (Fig 4 data).
#[derive(Debug, Clone, Copy, Default)]
pub struct StageTimes {
    pub preprocess: Duration,
    pub inference: Duration,
    pub postprocess: Duration,
}

/// The finished response.
#[derive(Debug, Clone)]
pub struct ServingResponse {
    pub id: u64,
    /// Generated summary token ids (EOS-trimmed).
    pub summary_ids: Vec<u32>,
    /// Detokenized summary text.
    pub summary_text: String,
    /// End-to-end latency (enqueue -> postprocess complete).
    pub latency: Duration,
    /// Positional token accuracy vs. the reference summary, if known.
    pub accuracy: Option<f64>,
    /// Inference failure, if the batch carrying this request errored.
    /// Failed requests still get a reply (never a silent drop), with
    /// empty `summary_ids`/`summary_text`.
    pub error: Option<String>,
}

impl ServingResponse {
    /// The reply for a request whose batch failed in the inference
    /// stage: empty summary, the failure message attached.
    pub fn failed(id: u64, latency: Duration, message: String) -> Self {
        Self {
            id,
            summary_ids: Vec::new(),
            summary_text: String::new(),
            latency,
            accuracy: None,
            error: Some(message),
        }
    }
}

/// Positional token accuracy: fraction of reference positions the
/// generation got right (the quality guard for fp16/pruning — §4
/// "maintaining high levels of performance").
pub fn summary_accuracy(generated: &[u32], reference: &[u32]) -> f64 {
    if reference.is_empty() {
        return if generated.is_empty() { 1.0 } else { 0.0 };
    }
    let hits = generated
        .iter()
        .zip(reference)
        .filter(|(g, r)| g == r)
        .count();
    hits as f64 / reference.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accuracy_perfect_and_partial() {
        assert_eq!(summary_accuracy(&[1, 2, 3], &[1, 2, 3]), 1.0);
        assert_eq!(summary_accuracy(&[1, 9, 3], &[1, 2, 3]), 2.0 / 3.0);
        assert_eq!(summary_accuracy(&[], &[1, 2]), 0.0);
        assert_eq!(summary_accuracy(&[], &[]), 1.0);
        // generation longer than reference: extra tokens don't add credit
        assert_eq!(summary_accuracy(&[1, 2, 3, 4], &[1, 2]), 1.0);
    }

    #[test]
    fn need_seq_adds_generation_budget() {
        let r = PreparedRequest {
            id: 0,
            prompt: vec![1; 10],
            max_new_tokens: 6,
            reference_summary: None,
            enqueued: Instant::now(),
        };
        assert_eq!(r.need_seq(), 16);
    }
}
