//! Dynamic batcher with length bucketing.
//!
//! The paper's two processing-level levers live here:
//! - **dynamic batch size** (§2.3): flush on max-size OR timeout, so load
//!   spikes batch densely and trickles don't wait forever;
//! - **allocation of data inference order** (§1): requests are grouped by
//!   the sequence bucket they need, so short prompts don't pay the
//!   padding of long ones (measured by the A2 bench).
//!
//! Batches leaving here are only the ARRIVAL grouping: the continuous
//! batcher ([`crate::coordinator::dispatch`]) is free to merge them
//! into already-running decode sessions between steps — see its module
//! docs for the admission policy.

use std::collections::VecDeque;
use std::time::Instant;

use super::request::PreparedRequest;
use crate::config::BatchPolicy;

/// A batch aimed at one (batch, seq) bucket.
#[derive(Debug, Clone)]
pub struct Batch {
    pub requests: Vec<PreparedRequest>,
    /// Sequence bucket the batch was aimed at.
    pub seq_bucket: usize,
}

impl Batch {
    pub fn len(&self) -> usize {
        self.requests.len()
    }

    pub fn is_empty(&self) -> bool {
        self.requests.is_empty()
    }

    /// Padding waste: fraction of token slots that are padding when this
    /// batch runs at its bucket.
    pub fn padding_waste(&self) -> f64 {
        if self.requests.is_empty() {
            return 0.0;
        }
        let used: usize = self.requests.iter().map(|r| r.need_seq()).sum();
        let cap = self.requests.len() * self.seq_bucket;
        1.0 - used as f64 / cap as f64
    }
}

/// Accumulates prepared requests and emits bucket-aligned batches.
pub struct DynamicBatcher {
    policy: BatchPolicy,
    /// Available sequence buckets (ascending), from the manifest.
    seq_buckets: Vec<usize>,
    /// One FIFO queue per sequence bucket (length_bucketing=true) or a
    /// single global FIFO (index 0) otherwise.
    queues: Vec<VecDeque<PreparedRequest>>,
    /// Arrival time of the oldest waiting request per queue.
    oldest: Vec<Option<Instant>>,
}

impl DynamicBatcher {
    pub fn new(policy: BatchPolicy, mut seq_buckets: Vec<usize>) -> Self {
        seq_buckets.sort_unstable();
        let n = if policy.length_bucketing { seq_buckets.len() } else { 1 };
        Self {
            policy,
            seq_buckets,
            queues: (0..n).map(|_| VecDeque::new()).collect(),
            oldest: vec![None; n],
        }
    }

    /// Smallest bucket that fits `need` tokens (falls back to largest —
    /// the engine will truncate/fail explicitly, not silently).
    pub fn bucket_for(&self, need: usize) -> usize {
        for (i, &b) in self.seq_buckets.iter().enumerate() {
            if need <= b {
                return i;
            }
        }
        self.seq_buckets.len() - 1
    }

    pub fn push(&mut self, req: PreparedRequest) {
        let qi = if self.policy.length_bucketing {
            self.bucket_for(req.need_seq())
        } else {
            0
        };
        if self.queues[qi].is_empty() {
            // age from the request's enqueue time (same clock drain()
            // uses for leftovers), not from when it reached the batcher
            self.oldest[qi] = Some(req.enqueued);
        }
        self.queues[qi].push_back(req);
    }

    pub fn pending(&self) -> usize {
        self.queues.iter().map(|q| q.len()).sum()
    }

    /// Emit the next batch according to the policy:
    /// - any queue at `max_batch` flushes immediately;
    /// - else the queue whose head has waited longest flushes once past
    ///   `max_wait_ms` (or if `force`).
    pub fn pop(&mut self, force: bool) -> Option<Batch> {
        // full queue first
        for qi in 0..self.queues.len() {
            if self.queues[qi].len() >= self.policy.max_batch {
                return Some(self.drain(qi));
            }
        }
        // timeout / forced flush: oldest head wins
        let mut best: Option<(usize, Instant)> = None;
        for qi in 0..self.queues.len() {
            if let (false, Some(t)) = (self.queues[qi].is_empty(), self.oldest[qi]) {
                if best.map_or(true, |(_, bt)| t < bt) {
                    best = Some((qi, t));
                }
            }
        }
        let (qi, t) = best?;
        let waited = t.elapsed().as_millis() as u64;
        if force || waited >= self.policy.max_wait_ms {
            return Some(self.drain(qi));
        }
        None
    }

    /// Size-based variant for offline drains: emit only FULL batches
    /// unless `force` (never timeout-flushes — composition is then
    /// independent of inference timing).
    pub fn pop_full_or(&mut self, force: bool) -> Option<Batch> {
        for qi in 0..self.queues.len() {
            if self.queues[qi].len() >= self.policy.max_batch {
                return Some(self.drain(qi));
            }
        }
        if force {
            for qi in 0..self.queues.len() {
                if !self.queues[qi].is_empty() {
                    return Some(self.drain(qi));
                }
            }
        }
        None
    }

    fn drain(&mut self, qi: usize) -> Batch {
        let mut take = self.policy.max_batch.min(self.queues[qi].len());
        if self.policy.max_batch_tokens > 0 {
            // stop before the token-footprint cap; always emit >= 1
            let mut tokens = 0usize;
            let mut n = 0usize;
            for r in self.queues[qi].iter().take(take) {
                tokens += r.need_seq();
                if n > 0 && tokens > self.policy.max_batch_tokens {
                    break;
                }
                n += 1;
            }
            take = n.max(1);
        }
        let requests: Vec<PreparedRequest> =
            self.queues[qi].drain(..take).collect();
        // leftovers (common with a token cap) keep their real age so the
        // timeout flush doesn't restart from zero per emitted batch
        self.oldest[qi] = self.queues[qi].front().map(|r| r.enqueued);
        let seq_bucket = if self.policy.length_bucketing {
            self.seq_buckets[qi]
        } else {
            // global FIFO: bucket = what the longest member needs
            let need =
                requests.iter().map(|r| r.need_seq()).max().unwrap_or(1);
            self.seq_buckets[self.bucket_for(need)]
        };
        Batch { requests, seq_bucket }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, prompt_len: usize) -> PreparedRequest {
        PreparedRequest::new(id, vec![5; prompt_len], 4)
    }

    fn policy(max_batch: usize, bucketing: bool) -> BatchPolicy {
        BatchPolicy {
            max_batch,
            max_wait_ms: 10_000,
            length_bucketing: bucketing,
            ..BatchPolicy::default()
        }
    }

    #[test]
    fn flushes_on_full_batch() {
        let mut b = DynamicBatcher::new(policy(2, true), vec![32, 64, 128]);
        b.push(req(1, 10));
        assert!(b.pop(false).is_none()); // not full, not timed out
        b.push(req(2, 12));
        let batch = b.pop(false).unwrap();
        assert_eq!(batch.len(), 2);
        assert_eq!(batch.seq_bucket, 32);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn force_flushes_partial() {
        let mut b = DynamicBatcher::new(policy(8, true), vec![32, 64]);
        b.push(req(1, 10));
        let batch = b.pop(true).unwrap();
        assert_eq!(batch.len(), 1);
    }

    #[test]
    fn length_bucketing_separates_queues() {
        let mut b = DynamicBatcher::new(policy(2, true), vec![32, 64, 128]);
        b.push(req(1, 10)); // bucket 32
        b.push(req(2, 60)); // bucket 64
        assert!(b.pop(false).is_none()); // neither queue full
        b.push(req(3, 12)); // bucket 32 now full
        let batch = b.pop(false).unwrap();
        assert_eq!(batch.seq_bucket, 32);
        assert_eq!(batch.requests.iter().map(|r| r.id).collect::<Vec<_>>(), vec![1, 3]);
    }

    #[test]
    fn fifo_mode_mixes_lengths() {
        let mut b = DynamicBatcher::new(policy(2, false), vec![32, 64, 128]);
        b.push(req(1, 10));
        b.push(req(2, 100));
        let batch = b.pop(false).unwrap();
        // bucket must cover the longest request
        assert_eq!(batch.seq_bucket, 128);
        // short request pays heavy padding — that's the waste A2 measures
        assert!(batch.padding_waste() > 0.3);
    }

    #[test]
    fn oversized_request_goes_to_largest_bucket() {
        let mut b = DynamicBatcher::new(policy(1, true), vec![32, 64]);
        b.push(req(1, 1000));
        let batch = b.pop(true).unwrap();
        assert_eq!(batch.seq_bucket, 64);
    }

    #[test]
    fn drain_respects_max_batch() {
        let mut b = DynamicBatcher::new(policy(2, true), vec![32]);
        for i in 0..5 {
            b.push(req(i, 8));
        }
        assert_eq!(b.pop(false).unwrap().len(), 2);
        assert_eq!(b.pending(), 3);
    }

    #[test]
    fn drain_respects_token_cap() {
        let mut p = policy(8, true);
        p.max_batch_tokens = 30; // each req needs 8 + 4 = 12 tokens
        let mut b = DynamicBatcher::new(p, vec![32]);
        for i in 0..8 {
            b.push(req(i, 8));
        }
        let batch = b.pop(false).unwrap(); // queue at max_batch -> flush
        assert_eq!(batch.len(), 2, "2 * 12 <= 30 < 3 * 12");
        // a single oversized request still goes out alone
        let mut p = policy(8, true);
        p.max_batch_tokens = 4;
        let mut b = DynamicBatcher::new(p, vec![32]);
        b.push(req(0, 8));
        assert_eq!(b.pop(true).unwrap().len(), 1);
    }
}
