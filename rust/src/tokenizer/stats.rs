//! Token-frequency statistics — the measurement side of §3.2's
//! embedding-layer pruning ("the embedding layer contains a large number
//! of rarely used characters").  `examples/pruning_analysis.rs` and the
//! A1 bench build coverage curves from this.

/// Cumulative-coverage sample: keeping ids `< vocab_prefix` retains
/// `coverage` of all token occurrences.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CoveragePoint {
    pub vocab_prefix: usize,
    pub coverage: f64,
}

/// Streaming frequency counter over token ids.
#[derive(Debug, Clone)]
pub struct FreqStats {
    counts: Vec<u64>,
    total: u64,
}

impl FreqStats {
    pub fn new(vocab_size: usize) -> Self {
        Self { counts: vec![0; vocab_size], total: 0 }
    }

    pub fn observe(&mut self, ids: &[u32]) {
        for &id in ids {
            if (id as usize) < self.counts.len() {
                self.counts[id as usize] += 1;
                self.total += 1;
            }
        }
    }

    pub fn total(&self) -> u64 {
        self.total
    }

    pub fn count_of(&self, id: u32) -> u64 {
        self.counts.get(id as usize).copied().unwrap_or(0)
    }

    /// Fraction of observed tokens whose id is `< prefix`.
    pub fn coverage_at(&self, prefix: usize) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        let kept: u64 = self.counts[..prefix.min(self.counts.len())]
            .iter()
            .sum();
        kept as f64 / self.total as f64
    }

    /// Coverage curve at the given prefix sizes.
    pub fn coverage_curve(&self, prefixes: &[usize]) -> Vec<CoveragePoint> {
        prefixes
            .iter()
            .map(|&p| CoveragePoint { vocab_prefix: p, coverage: self.coverage_at(p) })
            .collect()
    }

    /// Smallest prefix achieving at least `target` coverage.
    pub fn prefix_for_coverage(&self, target: f64) -> usize {
        let mut acc = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            acc += c;
            if self.total > 0 && acc as f64 / self.total as f64 >= target {
                return i + 1;
            }
        }
        self.counts.len()
    }

    /// Ids sorted by descending frequency (sanity check: for the synthetic
    /// Zipf corpus this should be ~identity on the word range).
    pub fn rank_order(&self) -> Vec<u32> {
        let mut ids: Vec<u32> = (0..self.counts.len() as u32).collect();
        ids.sort_by_key(|&i| std::cmp::Reverse(self.counts[i as usize]));
        ids
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn coverage_monotone_and_bounded() {
        let mut s = FreqStats::new(10);
        s.observe(&[4, 4, 4, 5, 5, 9]);
        assert_eq!(s.total(), 6);
        assert_eq!(s.coverage_at(0), 0.0);
        assert!((s.coverage_at(5) - 0.5).abs() < 1e-9);
        assert!((s.coverage_at(6) - 5.0 / 6.0).abs() < 1e-9);
        assert_eq!(s.coverage_at(10), 1.0);
        assert_eq!(s.coverage_at(99), 1.0);
    }

    #[test]
    fn prefix_for_coverage_finds_min() {
        let mut s = FreqStats::new(10);
        s.observe(&[4, 4, 4, 5, 5, 9]);
        assert_eq!(s.prefix_for_coverage(0.5), 5);
        assert_eq!(s.prefix_for_coverage(0.83), 6);
        assert_eq!(s.prefix_for_coverage(1.0), 10);
    }

    #[test]
    fn out_of_range_ids_ignored() {
        let mut s = FreqStats::new(4);
        s.observe(&[1, 2, 99]);
        assert_eq!(s.total(), 2);
    }

    #[test]
    fn empty_stats() {
        let s = FreqStats::new(4);
        assert_eq!(s.coverage_at(4), 0.0);
        assert_eq!(s.prefix_for_coverage(0.9), 4);
    }
}
