//! Reference WordPiece encoder: greedy longest-match with repeated
//! substring + hash probes.  This is the *baseline* tokenizer the fast
//! trie version is benchmarked against (components bench / A1).

use super::vocab::Vocab;
use super::{normalize, Encode};

/// Textbook greedy longest-match tokenizer.
pub struct SlowTokenizer {
    vocab: Vocab,
}

impl SlowTokenizer {
    pub fn new(vocab: Vocab) -> Self {
        Self { vocab }
    }

    pub fn vocab(&self) -> &Vocab {
        &self.vocab
    }

    fn encode_word(&self, word: &str, max_id: u32, out: &mut Vec<u32>) {
        // whole-word fast path
        if let Some(id) = self.vocab.id_of(word) {
            if id < max_id {
                out.push(id);
                return;
            }
        }
        // greedy longest-match over progressively shorter prefixes —
        // O(n^2) substring hashing, the cost LinMaxMatch removes.
        let bytes = word.as_bytes();
        let mut start = 0;
        while start < bytes.len() {
            let mut end = bytes.len();
            let mut matched = None;
            while end > start {
                let piece = &word[start..end];
                if let Some(id) = self.vocab.id_of(piece) {
                    if id < max_id {
                        matched = Some((id, end));
                        break;
                    }
                }
                end -= 1;
            }
            match matched {
                Some((id, e)) => {
                    out.push(id);
                    start = e;
                }
                None => {
                    // unmatchable character (cannot happen for generator
                    // output): skip one byte
                    start += 1;
                }
            }
        }
    }
}

impl Encode for SlowTokenizer {
    fn encode(&self, text: &str, max_id: u32) -> Vec<u32> {
        let norm = normalize(text);
        let mut out = Vec::with_capacity(norm.len() / 4 + 1);
        for word in norm.split(' ') {
            if !word.is_empty() {
                self.encode_word(word, max_id, &mut out);
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::special::FIRST_WORD;
    use crate::tokenizer::vocab::render_rank;

    fn tok(size: usize) -> SlowTokenizer {
        SlowTokenizer::new(Vocab::synthetic(size))
    }

    #[test]
    fn known_words_map_to_their_ids() {
        let t = tok(1000);
        let text = format!("{} {}", render_rank(0), render_rank(500));
        assert_eq!(
            t.encode(&text, 1000),
            vec![FIRST_WORD, FIRST_WORD + 500]
        );
    }

    #[test]
    fn pruned_words_resegment_into_pieces() {
        let t = tok(8000);
        // pick a word whose id is beyond a cutoff of 100
        let big = render_rank(6000); // multi-syllable, id 6004
        let ids = t.encode(&big, 100);
        assert!(!ids.is_empty());
        assert!(ids.iter().all(|&i| i < 100 && i >= FIRST_WORD));
        // pieces re-render to the same string
        let joined: String = ids
            .iter()
            .map(|&i| t.vocab().render(i).unwrap())
            .collect();
        assert_eq!(joined, big);
    }

    #[test]
    fn empty_and_whitespace() {
        let t = tok(1000);
        assert!(t.encode("", 1000).is_empty());
        assert!(t.encode("   \n\t", 1000).is_empty());
    }

    #[test]
    fn garbage_characters_skipped() {
        let t = tok(1000);
        // 'x' is not in the consonant/vowel alphabet: normalization keeps
        // it (a letter) but no piece can match; encoder skips it.
        let ids = t.encode("xx ba", 1000);
        assert_eq!(ids, vec![FIRST_WORD]);
    }
}
