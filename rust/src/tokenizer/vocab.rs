//! The synthetic vocabulary: deterministic id <-> surface-form mapping.
//!
//! Surface forms are built from a 64-syllable alphabet (8 consonants x
//! 8 vowels, every syllable exactly 2 chars), composed positionally in
//! little-endian base 64.  All of:
//!   - unambiguous segmentation (even char boundaries),
//!   - guaranteed sub-word fallback (every syllable is itself a word with
//!     a small id, so it survives any reasonable pruning cutoff),
//!   - O(1) rendering without a stored wordlist,
//! fall out of this construction.

use std::collections::HashMap;

use crate::special::FIRST_WORD;

pub const CONSONANTS: [char; 8] = ['b', 'd', 'f', 'g', 'k', 'm', 'n', 's'];
pub const VOWELS: [char; 8] = ['a', 'e', 'i', 'o', 'u', 'y', 'r', 'l'];
/// 8 x 8 two-character syllables.
pub const N_SYLLABLES: usize = 64;

/// Render syllable index 0..64 as its two characters.
fn syllable(idx: usize) -> [char; 2] {
    [CONSONANTS[idx / 8], VOWELS[idx % 8]]
}

/// The vocabulary: id space `[0, size)`, ids `< FIRST_WORD` are specials,
/// ids `>= FIRST_WORD` are words ranked by corpus frequency.
#[derive(Debug, Clone)]
pub struct Vocab {
    size: usize,
    /// surface form -> id, for every word id in `[FIRST_WORD, size)`.
    lookup: HashMap<String, u32>,
}

impl Vocab {
    /// Build the synthetic vocabulary of `size` ids.
    pub fn synthetic(size: usize) -> Self {
        assert!(size as u64 >= FIRST_WORD as u64 + 64, "vocab too small");
        let mut lookup = HashMap::with_capacity(size);
        for id in FIRST_WORD..size as u32 {
            lookup.insert(render_rank((id - FIRST_WORD) as usize), id);
        }
        Self { size, lookup }
    }

    pub fn size(&self) -> usize {
        self.size
    }

    /// Surface form of a word id (None for specials / out of range).
    pub fn render(&self, id: u32) -> Option<String> {
        if id < FIRST_WORD || id as usize >= self.size {
            return None;
        }
        Some(render_rank((id - FIRST_WORD) as usize))
    }

    /// id of an exact surface form.
    pub fn id_of(&self, word: &str) -> Option<u32> {
        self.lookup.get(word).copied()
    }

    /// Iterate (surface form, id) pairs — used to build the trie.
    pub fn iter(&self) -> impl Iterator<Item = (&String, u32)> {
        self.lookup.iter().map(|(s, &i)| (s, i))
    }
}

/// Word rank -> surface form (little-endian base-64 syllable digits).
pub fn render_rank(rank: usize) -> String {
    let mut s = String::with_capacity(6);
    let mut n = rank;
    loop {
        let [c, v] = syllable(n % N_SYLLABLES);
        s.push(c);
        s.push(v);
        n /= N_SYLLABLES;
        if n == 0 {
            break;
        }
        n -= 1; // bijective base-64: no leading-zero ambiguity
    }
    s
}

/// Surface form -> word rank (inverse of [`render_rank`]); None if the
/// string is not a well-formed word.
pub fn parse_rank(word: &str) -> Option<usize> {
    let chars: Vec<char> = word.chars().collect();
    if chars.is_empty() || chars.len() % 2 != 0 {
        return None;
    }
    let mut digits = Vec::with_capacity(chars.len() / 2);
    for pair in chars.chunks(2) {
        let c = CONSONANTS.iter().position(|&x| x == pair[0])?;
        let v = VOWELS.iter().position(|&x| x == pair[1])?;
        digits.push(c * 8 + v);
    }
    // invert bijective little-endian base 64
    let mut rank = 0usize;
    for &d in digits.iter().rev() {
        rank = rank * N_SYLLABLES + d + 1;
    }
    Some(rank - 1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn render_parse_roundtrip() {
        for rank in (0..5000).chain([64, 63, 65, 4095, 4096, 262143]) {
            let s = render_rank(rank);
            assert_eq!(parse_rank(&s), Some(rank), "rank {rank} -> {s}");
        }
    }

    #[test]
    fn renders_are_unique_and_even_length() {
        let mut seen = std::collections::HashSet::new();
        for rank in 0..10_000 {
            let s = render_rank(rank);
            assert!(s.len() % 2 == 0 && !s.is_empty());
            assert!(seen.insert(s), "collision at rank {rank}");
        }
    }

    #[test]
    fn single_syllable_words_are_lowest_ranks() {
        for rank in 0..N_SYLLABLES {
            assert_eq!(render_rank(rank).len(), 2);
        }
        assert_eq!(render_rank(N_SYLLABLES).len(), 4);
    }

    #[test]
    fn vocab_lookup_matches_render() {
        let v = Vocab::synthetic(1000);
        for id in crate::special::FIRST_WORD..1000 {
            let s = v.render(id).unwrap();
            assert_eq!(v.id_of(&s), Some(id));
        }
        assert_eq!(v.render(0), None);
        assert_eq!(v.render(1000), None);
    }

    #[test]
    fn parse_rejects_garbage() {
        assert_eq!(parse_rank(""), None);
        assert_eq!(parse_rank("x"), None);
        assert_eq!(parse_rank("bax"), None);
        assert_eq!(parse_rank("ab"), None); // vowel-first
    }
}
