//! Text normalization: the cheap, allocation-light cleanup pass both
//! tokenizers share (the real Faster Tokenizer fuses this with matching;
//! we keep it separate so the benches can attribute cost per phase).

/// Lowercase ASCII, collapse all whitespace runs to single spaces, strip
/// every character outside the synthetic alphabet (letters survive,
/// punctuation/digits drop — matching how the corpus generator writes).
pub fn normalize(text: &str) -> String {
    let mut out = String::with_capacity(text.len());
    let mut pending_space = false;
    for ch in text.chars() {
        if ch.is_whitespace() {
            pending_space = !out.is_empty();
            continue;
        }
        let ch = ch.to_ascii_lowercase();
        if ch.is_ascii_lowercase() {
            if pending_space {
                out.push(' ');
                pending_space = false;
            }
            out.push(ch);
        }
        // anything else (digits, punctuation, non-ascii) is dropped
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn collapses_whitespace() {
        assert_eq!(normalize("ba  be\t\nbi"), "ba be bi");
    }

    #[test]
    fn lowercases() {
        assert_eq!(normalize("Ba BE"), "ba be");
    }

    #[test]
    fn strips_non_letters() {
        assert_eq!(normalize("ba, be! 42 bi?"), "ba be bi");
    }

    #[test]
    fn no_leading_or_trailing_space() {
        assert_eq!(normalize("  ba be  "), "ba be");
        assert_eq!(normalize(""), "");
        assert_eq!(normalize("   "), "");
    }
}
