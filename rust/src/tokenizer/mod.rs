//! Tokenizer substrate — the "Faster Tokenizer" axis of the paper (§2.3).
//!
//! The synthetic language (see [`crate::data`]) writes every word as a
//! concatenation of two-character syllables from a fixed 64-syllable
//! alphabet, and the model vocabulary assigns ids in corpus-frequency
//! order (rank == id), which is exactly the property that makes the
//! paper's embedding-layer pruning a *prefix* slice (§3.2).
//!
//! Two interchangeable encoders over the same [`Vocab`]:
//!
//! - [`wordpiece::SlowTokenizer`] — textbook greedy longest-match
//!   WordPiece: repeated substring + hash probes per word (the
//!   reference implementation and the baseline in the A1/components
//!   benches).
//! - [`fast::FastTokenizer`] — single-pass trie matcher in the spirit of
//!   LinMaxMatch (Song et al., "Fast WordPiece Tokenization"), no
//!   per-word allocation on the hot path.
//!
//! Both support a `max_id` cutoff: with the pruned engine, words whose id
//! fell outside the retained prefix are re-segmented into high-frequency
//! pieces (single syllables always survive pruning), so the pruned model
//! serves the SAME text — slightly longer token sequences instead of
//! unknown tokens.

pub mod fast;
mod normalizer;
mod stats;
pub mod vocab;
pub mod wordpiece;

pub use fast::FastTokenizer;
pub use normalizer::normalize;
pub use stats::{CoveragePoint, FreqStats};
pub use vocab::{Vocab, N_SYLLABLES};
pub use wordpiece::SlowTokenizer;

use crate::Result;

/// Common interface so engines/benches can swap implementations.
pub trait Encode {
    /// Text -> token ids, using only ids `< max_id` (pass `vocab.size()`
    /// for the unpruned model).  Always succeeds on normalizable text:
    /// unknown single characters are dropped (they cannot occur in
    /// generator output, only in adversarial input).
    fn encode(&self, text: &str, max_id: u32) -> Vec<u32>;
}

/// Token ids -> text (shared by both tokenizers; decoding is not on the
/// benchmarked hot path).
pub fn decode(vocab: &Vocab, ids: &[u32]) -> String {
    let mut out = String::new();
    for &id in ids {
        if id < crate::special::FIRST_WORD {
            continue; // specials render as nothing
        }
        if let Some(w) = vocab.render(id) {
            if !out.is_empty() {
                out.push(' ');
            }
            out.push_str(&w);
        }
    }
    out
}

/// Convenience: build the default (vocab-complete) fast tokenizer for a
/// model vocabulary size.
pub fn default_fast(vocab_size: usize) -> Result<FastTokenizer> {
    Ok(FastTokenizer::new(Vocab::synthetic(vocab_size)))
}
