//! Fast single-pass tokenizer: byte trie + longest-match backtracking,
//! in the spirit of LinMaxMatch (Song et al. 2020), the algorithm behind
//! the paper's "Faster Tokenizer" (§2.3).
//!
//! Differences from [`super::wordpiece::SlowTokenizer`] (same output,
//! verified by a proptest):
//! - one left-to-right walk over the bytes of each word; no substring
//!   allocation, no repeated hashing,
//! - trie nodes are flat `[u32; 26]` child tables (arena-indexed), so a
//!   step is one array load,
//! - longest-accepting-state is tracked during the walk, giving greedy
//!   longest-match on failure without rescanning.

use super::vocab::Vocab;
use super::{normalize, Encode};

const NO_NODE: u32 = u32::MAX;
const NO_ID: u32 = u32::MAX;

struct Node {
    children: [u32; 26],
    /// Word id accepted at this node (NO_ID if none).
    id: u32,
}

impl Node {
    fn new() -> Self {
        Self { children: [NO_NODE; 26], id: NO_ID }
    }
}

/// Trie-based tokenizer. Build once per vocabulary, reuse everywhere
/// (it is `Send + Sync`; stages share it via `Arc`).
pub struct FastTokenizer {
    vocab: Vocab,
    arena: Vec<Node>,
}

impl FastTokenizer {
    pub fn new(vocab: Vocab) -> Self {
        let mut arena = vec![Node::new()];
        for (word, id) in vocab.iter() {
            let mut cur = 0usize;
            for &b in word.as_bytes() {
                let c = (b - b'a') as usize;
                let next = arena[cur].children[c];
                cur = if next == NO_NODE {
                    arena.push(Node::new());
                    let idx = (arena.len() - 1) as u32;
                    arena[cur].children[c] = idx;
                    idx as usize
                } else {
                    next as usize
                };
            }
            arena[cur].id = id;
        }
        Self { vocab, arena }
    }

    pub fn vocab(&self) -> &Vocab {
        &self.vocab
    }

    pub fn node_count(&self) -> usize {
        self.arena.len()
    }

    #[inline]
    fn encode_word(&self, word: &[u8], max_id: u32, out: &mut Vec<u32>) {
        let mut start = 0usize;
        while start < word.len() {
            let mut cur = 0usize;
            let mut best: Option<(u32, usize)> = None; // (id, end)
            let mut i = start;
            while i < word.len() {
                let b = word[i];
                if !(b'a'..=b'z').contains(&b) {
                    break;
                }
                let next = self.arena[cur].children[(b - b'a') as usize];
                if next == NO_NODE {
                    break;
                }
                cur = next as usize;
                i += 1;
                let id = self.arena[cur].id;
                if id != NO_ID && id < max_id {
                    best = Some((id, i));
                }
            }
            match best {
                Some((id, end)) => {
                    out.push(id);
                    start = end;
                }
                None => start += 1, // unmatchable byte: skip
            }
        }
    }
}

impl Encode for FastTokenizer {
    fn encode(&self, text: &str, max_id: u32) -> Vec<u32> {
        let norm = normalize(text);
        let mut out = Vec::with_capacity(norm.len() / 4 + 1);
        for word in norm.as_bytes().split(|&b| b == b' ') {
            if !word.is_empty() {
                self.encode_word(word, max_id, &mut out);
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::special::FIRST_WORD;
    use crate::tokenizer::vocab::render_rank;
    use crate::tokenizer::SlowTokenizer;

    #[test]
    fn matches_slow_tokenizer_on_generated_words() {
        let vocab = Vocab::synthetic(4000);
        let fast = FastTokenizer::new(vocab.clone());
        let slow = SlowTokenizer::new(vocab);
        for rank in [0usize, 1, 63, 64, 100, 999, 3000, 3995] {
            let w = render_rank(rank);
            assert_eq!(
                fast.encode(&w, 4000),
                slow.encode(&w, 4000),
                "rank {rank}"
            );
            // and under a pruning cutoff
            assert_eq!(fast.encode(&w, 200), slow.encode(&w, 200));
        }
    }

    #[test]
    fn whole_word_preferred_over_pieces() {
        let fast = FastTokenizer::new(Vocab::synthetic(8000));
        let w = render_rank(5000); // multi-syllable word
        assert_eq!(fast.encode(&w, 8000), vec![FIRST_WORD + 5000]);
    }

    #[test]
    fn resegmentation_preserves_surface_form() {
        let fast = FastTokenizer::new(Vocab::synthetic(8000));
        let w = render_rank(7321);
        let ids = fast.encode(&w, 500);
        let joined: String = ids
            .iter()
            .map(|&i| fast.vocab().render(i).unwrap())
            .collect();
        assert_eq!(joined, w);
    }

    #[test]
    fn multiword_text() {
        let fast = FastTokenizer::new(Vocab::synthetic(1000));
        let text = format!("{} {} {}", render_rank(3), render_rank(40), render_rank(700));
        assert_eq!(
            fast.encode(&text, 1000),
            vec![FIRST_WORD + 3, FIRST_WORD + 40, FIRST_WORD + 700]
        );
    }
}
