//! Property-based tests (hand-rolled generators over the in-crate PRNG —
//! the offline vendor set has no proptest).  Each property runs a few
//! hundred randomized cases with a fixed seed, so failures reproduce.

use std::collections::HashMap;
use std::sync::{mpsc, Arc};

use aigc_infer::config::{
    BatchPolicy, EngineKind, GenConfig, KvConfig, ServingConfig,
};
use aigc_infer::coordinator::{
    Batch, DynamicBatcher, InferencePool, PoolEvent, PreparedRequest,
};
use aigc_infer::engine::{
    build as build_engine, build_with_kv, DecodeSession, Engine,
    EngineInput, FinishReason, Sampler,
};
use aigc_infer::runtime::reference::model::{linear, logits_matvec};
use aigc_infer::runtime::{
    quantize_f16, Backend, DType, Kernel, RefBackend, WSlice, F16,
};
use aigc_infer::tokenizer::vocab::{parse_rank, render_rank};
use aigc_infer::tokenizer::{
    decode, Encode, FastTokenizer, SlowTokenizer, Vocab,
};
use aigc_infer::util::json::{self, Value};
use aigc_infer::util::rng::Rng;

const VOCAB: usize = 8000;

fn random_text(rng: &mut Rng, max_words: usize) -> String {
    let n = rng.gen_range(0, max_words + 1);
    let mut s = String::new();
    for i in 0..n {
        if i > 0 {
            s.push(' ');
        }
        // mix known words, rare words, and adversarial junk
        match rng.gen_range(0, 10) {
            0 => s.push_str("xqz"),                       // unmatchable
            1 => s.push_str(&render_rank(rng.gen_range(0, 300_000))), // OOV-huge
            _ => s.push_str(&render_rank(rng.gen_range(0, VOCAB - 4))),
        }
    }
    s
}

#[test]
fn prop_fast_equals_slow_tokenizer() {
    let vocab = Vocab::synthetic(VOCAB);
    let fast = FastTokenizer::new(vocab.clone());
    let slow = SlowTokenizer::new(vocab);
    let mut rng = Rng::seed_from_u64(0xF00D);
    for case in 0..300 {
        let text = random_text(&mut rng, 30);
        let max_id = [64u32 + 4, 500, 4000, 8000][case % 4];
        assert_eq!(
            fast.encode(&text, max_id),
            slow.encode(&text, max_id),
            "case {case}: text={text:?} max_id={max_id}"
        );
    }
}

#[test]
fn prop_tokenizer_roundtrip_on_vocab_words() {
    // decode(encode(text)) == normalized text for texts of known words
    let fast = FastTokenizer::new(Vocab::synthetic(VOCAB));
    let mut rng = Rng::seed_from_u64(0xBEEF);
    for _ in 0..200 {
        let n = rng.gen_range(1, 25);
        let words: Vec<String> = (0..n)
            .map(|_| render_rank(rng.gen_range(0, VOCAB - 4)))
            .collect();
        let text = words.join(" ");
        let ids = fast.encode(&text, VOCAB as u32);
        assert_eq!(decode(fast.vocab(), &ids), text);
    }
}

#[test]
fn prop_pruned_encoding_preserves_surface_and_ids_below_cutoff() {
    let fast = FastTokenizer::new(Vocab::synthetic(VOCAB));
    let mut rng = Rng::seed_from_u64(0xCAFE);
    for _ in 0..200 {
        let cutoff = rng.gen_range(68, VOCAB) as u32;
        let word = render_rank(rng.gen_range(0, VOCAB - 4));
        let ids = fast.encode(&word, cutoff);
        assert!(ids.iter().all(|&i| i >= 4 && i < cutoff));
        let joined: String = ids
            .iter()
            .map(|&i| fast.vocab().render(i).unwrap())
            .collect();
        assert_eq!(joined, word);
    }
}

#[test]
fn prop_render_parse_rank_bijection() {
    let mut rng = Rng::seed_from_u64(0xABCD);
    for _ in 0..2000 {
        let rank = rng.gen_range(0, 1_000_000);
        assert_eq!(parse_rank(&render_rank(rank)), Some(rank));
    }
}

#[test]
fn prop_batcher_conserves_requests() {
    // No request is lost or duplicated; every batch respects max_batch
    // and its bucket covers every member's need (or is the largest).
    let mut rng = Rng::seed_from_u64(0x5EED);
    for case in 0..100 {
        let max_batch = rng.gen_range(1, 10);
        let bucketing = case % 2 == 0;
        let policy = BatchPolicy {
            max_batch,
            max_wait_ms: 10_000,
            length_bucketing: bucketing,
            ..BatchPolicy::default()
        };
        let buckets = vec![32usize, 64, 128];
        let mut b = DynamicBatcher::new(policy, buckets.clone());
        let n = rng.gen_range(1, 100);
        let mut seen = vec![false; n];
        for id in 0..n {
            b.push(PreparedRequest::new(
                id as u64,
                vec![5; rng.gen_range(1, 140)],
                4,
            ));
        }
        let mut batches = Vec::new();
        while let Some(batch) = b.pop_full_or(false) {
            batches.push(batch);
        }
        while let Some(batch) = b.pop_full_or(true) {
            batches.push(batch);
        }
        assert_eq!(b.pending(), 0);
        for batch in &batches {
            assert!(batch.len() <= max_batch && !batch.is_empty());
            assert!(buckets.contains(&batch.seq_bucket));
            for r in &batch.requests {
                assert!(
                    !seen[r.id as usize],
                    "duplicate request {}",
                    r.id
                );
                seen[r.id as usize] = true;
                // bucket covers the request unless nothing can
                assert!(
                    r.need_seq() <= batch.seq_bucket
                        || batch.seq_bucket == *buckets.last().unwrap()
                );
            }
            let waste = batch.padding_waste();
            assert!((0.0..1.0).contains(&waste) || batch.seq_bucket == 128);
        }
        assert!(seen.iter().all(|&s| s), "lost requests in case {case}");
    }
}

#[test]
fn prop_batcher_never_exceeds_token_or_size_caps() {
    // With a token-footprint cap set, every emitted batch stays within
    // BOTH policy caps — except a single oversized request, which must
    // still ship (alone) rather than starve.
    let mut rng = Rng::seed_from_u64(0x70CA9);
    for case in 0..100 {
        let max_batch = rng.gen_range(1, 10);
        let max_batch_tokens = rng.gen_range(40, 400);
        let policy = BatchPolicy {
            max_batch,
            max_wait_ms: 10_000,
            length_bucketing: case % 2 == 0,
            max_batch_tokens,
        };
        let mut b = DynamicBatcher::new(policy, vec![32, 64, 128]);
        let n = rng.gen_range(1, 80);
        for id in 0..n {
            b.push(PreparedRequest::new(
                id as u64,
                vec![5; rng.gen_range(1, 140)],
                4,
            ));
        }
        let mut emitted = 0usize;
        while let Some(batch) = b.pop_full_or(true) {
            emitted += batch.len();
            assert!(!batch.is_empty());
            assert!(
                batch.len() <= max_batch,
                "case {case}: batch of {} > max_batch {max_batch}",
                batch.len()
            );
            let tokens: usize =
                batch.requests.iter().map(|r| r.need_seq()).sum();
            assert!(
                tokens <= max_batch_tokens || batch.len() == 1,
                "case {case}: {tokens} tokens over cap {max_batch_tokens} \
                 in a batch of {}",
                batch.len()
            );
        }
        assert_eq!(emitted, n, "case {case}: requests lost");
    }
}

fn random_json(rng: &mut Rng, depth: usize) -> Value {
    match if depth == 0 { rng.gen_range(0, 4) } else { rng.gen_range(0, 6) } {
        0 => Value::Null,
        1 => Value::Bool(rng.gen_f64() < 0.5),
        2 => Value::Num((rng.gen_f64() * 2e6).floor() - 1e6),
        3 => {
            let n = rng.gen_range(0, 12);
            let s: String = (0..n)
                .map(|_| {
                    let c = rng.gen_range(0, 100);
                    match c {
                        0 => '"',
                        1 => '\\',
                        2 => '\n',
                        3 => 'é',
                        4 => '😀',
                        _ => (b'a' + (c % 26) as u8) as char,
                    }
                })
                .collect();
            Value::Str(s)
        }
        4 => Value::Array(
            (0..rng.gen_range(0, 5))
                .map(|_| random_json(rng, depth - 1))
                .collect(),
        ),
        _ => Value::Object(
            (0..rng.gen_range(0, 5))
                .map(|i| (format!("k{i}"), random_json(rng, depth - 1)))
                .collect(),
        ),
    }
}

#[test]
fn prop_json_roundtrip() {
    let mut rng = Rng::seed_from_u64(0x12AB);
    for _ in 0..500 {
        let v = random_json(&mut rng, 3);
        let text = v.to_json();
        let back = json::parse(&text)
            .unwrap_or_else(|e| panic!("reparse failed: {e}\n{text}"));
        assert_eq!(back, v, "roundtrip mismatch for {text}");
    }
}

#[test]
fn prop_histogram_quantiles_monotone() {
    use aigc_infer::metrics::Histogram;
    use std::time::Duration;
    let mut rng = Rng::seed_from_u64(0x77AA);
    for _ in 0..50 {
        let mut h = Histogram::new();
        let n = rng.gen_range(1, 2000);
        for _ in 0..n {
            h.record(Duration::from_micros(rng.gen_range(1, 10_000_000) as u64));
        }
        let mut last = Duration::ZERO;
        for q in [0.0, 0.1, 0.25, 0.5, 0.75, 0.9, 0.95, 0.99, 1.0] {
            let v = h.quantile(q);
            assert!(v >= last, "quantile({q}) decreased");
            last = v;
        }
        assert!(h.quantile(1.0) <= h.max() + Duration::from_micros(1));
        assert!(h.mean() >= h.min() && h.mean() <= h.max());
    }
}

/// Random in-vocab prompts `[BOS] w… [SEP]` for engine-level properties.
fn random_inputs(rng: &mut Rng, n: usize, vocab: u32) -> Vec<EngineInput> {
    (0..n)
        .map(|i| {
            let len = rng.gen_range(1, 20);
            let mut prompt = vec![aigc_infer::special::BOS];
            for _ in 0..len {
                prompt.push(
                    aigc_infer::special::FIRST_WORD
                        + rng.gen_range(0, (vocab - 4) as usize) as u32,
                );
            }
            prompt.push(aigc_infer::special::SEP);
            EngineInput {
                request_id: i as u64,
                prompt,
                max_new_tokens: rng.gen_range(1, 10),
            }
        })
        .collect()
}

#[test]
fn prop_stepped_session_equals_one_shot_generate() {
    // THE step-API acceptance property: driving DecodeSession::step()
    // by hand to completion is token-identical to the one-shot
    // `generate` driver, across the full Table-1 engine ladder.
    let backend = Arc::new(RefBackend::synthetic());
    let pruned_vocab =
        backend.manifest().config_for("pruned").vocab_size as u32;
    let mut rng = Rng::seed_from_u64(0x57E9);
    for kind in
        [EngineKind::Baseline, EngineKind::FtFull, EngineKind::FtPruned]
    {
        let engine =
            build_engine(kind, backend.clone(), Default::default()).unwrap();
        for case in 0..8 {
            let n = rng.gen_range(1, 7);
            let inputs = random_inputs(&mut rng, n, pruned_vocab);
            let one_shot: Vec<Vec<u32>> = engine
                .generate(&inputs, &mut Sampler::greedy())
                .unwrap()
                .into_iter()
                .map(|o| o.generated)
                .collect();
            let mut sampler = Sampler::greedy();
            let mut session = engine.start(&inputs).unwrap();
            let mut stepped: Vec<Option<Vec<u32>>> =
                vec![None; inputs.len()];
            let mut streamed: Vec<Vec<u32>> =
                vec![Vec::new(); inputs.len()];
            let mut guard = 0;
            loop {
                for f in session.take_finished() {
                    stepped[f.seq] = Some(f.output.generated);
                }
                if session.active() == 0 {
                    break;
                }
                for ev in session.step(&mut sampler).unwrap() {
                    streamed[ev.request_id as usize].extend(ev.tokens);
                }
                guard += 1;
                assert!(guard < 1000, "{kind:?} case {case}: no progress");
            }
            let stepped: Vec<Vec<u32>> =
                stepped.into_iter().map(|o| o.unwrap()).collect();
            assert_eq!(
                one_shot, stepped,
                "{kind:?} case {case}: stepped != one-shot"
            );
            // the TokenEvent stream is the summary, token for token
            assert_eq!(
                streamed, stepped,
                "{kind:?} case {case}: events diverge from outputs"
            );
        }
    }
}

#[test]
fn prop_paged_and_contiguous_paths_are_bitwise_identical() {
    // THE paged-KV identity guarantee at the engine level: the paged
    // block-pool path and the legacy contiguous bucket path generate
    // bitwise-identical greedy streams across the FT ladder rungs, for
    // both storage dtypes, over randomized prompt sets — including odd
    // pool geometries (tiny blocks, tight pools).
    let fp32: Arc<dyn Backend> = Arc::new(RefBackend::synthetic());
    let fp16: Arc<dyn Backend> = {
        let mut b = RefBackend::synthetic();
        b.set_dtype(DType::F16);
        Arc::new(b)
    };
    let mut rng = Rng::seed_from_u64(0x9A6E);
    for (backend, dlabel) in [(&fp32, "fp32"), (&fp16, "fp16")] {
        let pruned_vocab =
            backend.manifest().config_for("pruned").vocab_size as u32;
        for kind in [EngineKind::FtFull, EngineKind::FtPruned] {
            let legacy = build_with_kv(
                kind,
                backend.clone(),
                Default::default(),
                KvConfig { paged: false, ..KvConfig::default() },
            )
            .unwrap();
            for case in 0..6 {
                // vary the pool geometry so block boundaries land in
                // the middle of prompts, at slot 0, everywhere
                let kv = KvConfig {
                    paged: true,
                    block_size: [1, 3, 16, 5][case % 4],
                    blocks: 0,
                    ..KvConfig::default()
                };
                let paged = build_with_kv(
                    kind,
                    backend.clone(),
                    Default::default(),
                    kv,
                )
                .unwrap();
                assert!(
                    paged.kv_geometry().is_some(),
                    "paged engine must report its pool geometry"
                );
                assert!(legacy.kv_geometry().is_none());
                let n = rng.gen_range(1, 6);
                let inputs = random_inputs(&mut rng, n, pruned_vocab);
                let a: Vec<Vec<u32>> = legacy
                    .generate(&inputs, &mut Sampler::greedy())
                    .unwrap()
                    .into_iter()
                    .map(|o| o.generated)
                    .collect();
                let b: Vec<Vec<u32>> = paged
                    .generate(&inputs, &mut Sampler::greedy())
                    .unwrap()
                    .into_iter()
                    .map(|o| o.generated)
                    .collect();
                assert_eq!(
                    a, b,
                    "{kind:?}/{dlabel} case {case}: paged diverged \
                     from contiguous"
                );
                assert!(
                    a.iter().map(|s| s.len()).sum::<usize>() > 0,
                    "{kind:?}/{dlabel} case {case}: vacuous comparison"
                );
            }
        }
    }
}

#[test]
fn prop_f16_roundtrip_rne_and_ordering() {
    // crate-boundary property sweep over the software binary16 type:
    // quantization is idempotent, error-bounded, and order-preserving
    let mut rng = Rng::seed_from_u64(0xF166);
    let mut prev: Option<f32> = None;
    let mut vals: Vec<f32> = (0..3000)
        .map(|_| ((rng.gen_f64() - 0.5) * 1e3) as f32)
        .collect();
    vals.sort_by(|a, b| a.partial_cmp(b).unwrap());
    for &v in &vals {
        let q = quantize_f16(v);
        // idempotent: a quantized value is exactly representable
        assert_eq!(quantize_f16(q), q, "{v}");
        // round-to-NEAREST error bound for normal-range values
        if v.abs() >= (2f32).powi(-14) {
            assert!(((q - v) / v).abs() <= 4.882_812_5e-4, "{v} -> {q}");
        }
        // monotone, so argmax over quantized logits never inverts a
        // pair that binary16 can still distinguish
        if let Some(p) = prev {
            assert!(quantize_f16(p) <= q, "order inverted at {p} -> {v}");
        }
        prev = Some(v);
        // F16's own comparison agrees with the f32 view
        assert_eq!(
            F16::from_f32(v).partial_cmp(&F16::from_f32(v + 1.0)),
            q.partial_cmp(&quantize_f16(v + 1.0))
        );
    }
}

#[test]
fn prop_session_fuzz_every_request_terminates_exactly_once() {
    // Seeded fuzz of the continuous-batching session contract: random
    // interleavings of admit / cancel / deadline-retire / step over a
    // few hundred decode steps.  Every admitted request must surface
    // EXACTLY ONE FinishedRequest, with a coherent reason.
    let backend = Arc::new(RefBackend::synthetic());
    let mut rng = Rng::seed_from_u64(0xFA22);
    for kind in
        [EngineKind::Baseline, EngineKind::FtFull, EngineKind::FtPruned]
    {
        let engine =
            build_engine(kind, backend.clone(), Default::default())
                .unwrap();
        // fresh fuzz inputs: short prompts, budgets 1..=6 with an
        // occasional zero-budget request (must retire at admission
        // with Length, before any decode work is spent on it)
        fn fresh(
            rng: &mut Rng,
            next_id: &mut u64,
            n: usize,
        ) -> Vec<EngineInput> {
            (0..n)
                .map(|_| {
                    let id = *next_id;
                    *next_id += 1;
                    let len = rng.gen_range(1, 8);
                    let mut prompt = vec![aigc_infer::special::BOS];
                    for _ in 0..len {
                        prompt.push(
                            aigc_infer::special::FIRST_WORD
                                + rng.gen_range(0, 80) as u32,
                        );
                    }
                    prompt.push(aigc_infer::special::SEP);
                    let max_new = if rng.gen_range(0, 10) == 0 {
                        0
                    } else {
                        rng.gen_range(1, 7)
                    };
                    EngineInput {
                        request_id: id,
                        prompt,
                        max_new_tokens: max_new,
                    }
                })
                .collect()
        }
        for case in 0..2 {
            let mut sampler = Sampler::greedy();
            let mut next_id = 0u64;
            let seed_batch =
                fresh(&mut rng, &mut next_id, 1 + rng.gen_range(0, 3));
            let mut live: Vec<u64> =
                seed_batch.iter().map(|i| i.request_id).collect();
            let mut session = engine.start(&seed_batch).unwrap();
            let mut outcomes: HashMap<u64, FinishReason> = HashMap::new();
            let mut drain =
                |session: &mut Box<dyn DecodeSession>,
                 live: &mut Vec<u64>,
                 outcomes: &mut HashMap<u64, FinishReason>| {
                    for f in session.take_finished() {
                        let id = f.output.request_id;
                        assert!(
                            outcomes.insert(id, f.reason).is_none(),
                            "{kind:?} case {case}: request {id} \
                             terminated twice"
                        );
                        live.retain(|&l| l != id);
                    }
                };
            let target = 24usize; // requests per fuzz case
            let mut steps = 0usize;
            loop {
                steps += 1;
                assert!(
                    steps < 500,
                    "{kind:?} case {case}: fuzz made no progress"
                );
                // random op between steps, like the pool's step loop
                match rng.gen_range(0, 6) {
                    0 | 1 if (next_id as usize) < target => {
                        let extra = fresh(
                            &mut rng,
                            &mut next_id,
                            1 + rng.gen_range(0, 2),
                        );
                        if session.can_admit(&extra) {
                            live.extend(
                                extra.iter().map(|i| i.request_id),
                            );
                            session.admit(&extra).unwrap();
                        } else {
                            // candidates never entered the session; the
                            // ids are simply never spent
                            next_id -= extra.len() as u64;
                        }
                    }
                    2 if !live.is_empty() => {
                        let id = live[rng.gen_range(0, live.len())];
                        let reason = if rng.gen_range(0, 2) == 0 {
                            FinishReason::Cancelled
                        } else {
                            FinishReason::DeadlineExpired
                        };
                        // false only when the row already finished but
                        // has not been drained yet (e.g. zero-budget
                        // admissions) — exactly the pool's semantics
                        let _ = session.retire(id, reason);
                    }
                    _ => {}
                }
                session.step(&mut sampler).unwrap();
                drain(&mut session, &mut live, &mut outcomes);
                if session.active() == 0 {
                    if (next_id as usize) >= target {
                        break;
                    }
                    // keep the session alive until the target is spent
                    let extra = fresh(&mut rng, &mut next_id, 1);
                    assert!(session.can_admit(&extra), "{kind:?}: empty \
                             session must admit a small request");
                    live.extend(extra.iter().map(|i| i.request_id));
                    session.admit(&extra).unwrap();
                }
            }
            drain(&mut session, &mut live, &mut outcomes);
            assert!(live.is_empty(), "{kind:?} case {case}: {live:?} \
                     never terminated");
            assert_eq!(
                outcomes.len(),
                next_id as usize,
                "{kind:?} case {case}: terminal count != submitted"
            );
            for (id, reason) in &outcomes {
                assert!(
                    matches!(
                        reason,
                        FinishReason::Eos
                            | FinishReason::Length
                            | FinishReason::Cancelled
                            | FinishReason::DeadlineExpired
                    ),
                    "request {id}: incoherent reason {reason:?}"
                );
            }
        }
    }
}

#[test]
fn prop_pool_fuzz_exactly_one_terminal_event_per_id() {
    // The same lifecycle contract at the pool level, with real worker
    // threads: randomized budgets, pre-cancelled requests and expired
    // deadlines interleave; every id gets exactly one terminal event
    // and never a token event after it.
    let mut cfg = ServingConfig::default();
    cfg.workers = 2;
    cfg.row_threads = 1;
    cfg.gen.max_new_tokens = 6;
    let (out_tx, out_rx) = mpsc::sync_channel(4096);
    let pool = InferencePool::start(&cfg, out_tx).unwrap();
    let input = pool.input();
    let collector =
        std::thread::spawn(move || -> Vec<PoolEvent> { out_rx.iter().collect() });

    let mut rng = Rng::seed_from_u64(0x9001);
    let mut submitted: Vec<u64> = Vec::new();
    let mut id = 0u64;
    for _ in 0..10 {
        let n = 1 + rng.gen_range(0, 4);
        let mut requests = Vec::new();
        for _ in 0..n {
            let len = 1 + rng.gen_range(0, 6);
            let mut prompt = vec![aigc_infer::special::BOS];
            for _ in 0..len {
                prompt.push(
                    aigc_infer::special::FIRST_WORD
                        + rng.gen_range(0, 60) as u32,
                );
            }
            prompt.push(aigc_infer::special::SEP);
            let mut req = PreparedRequest::new(
                id,
                prompt,
                1 + rng.gen_range(0, 6),
            );
            match rng.gen_range(0, 8) {
                0 => {
                    // pre-cancelled
                    req.cancel = Some(Arc::new(
                        std::sync::atomic::AtomicBool::new(true),
                    ));
                }
                1 => {
                    // already-expired deadline
                    req.deadline = Some(std::time::Instant::now());
                }
                _ => {}
            }
            submitted.push(id);
            id += 1;
            requests.push(req);
        }
        input.send(Batch { requests, seq_bucket: 32 }).unwrap();
    }
    drop(input);
    pool.join();
    let events = collector.join().unwrap();

    let mut terminals: HashMap<u64, usize> = HashMap::new();
    for ev in &events {
        match ev {
            PoolEvent::Tokens { id, .. } => {
                assert!(
                    !terminals.contains_key(id),
                    "request {id}: token event after its terminal"
                );
            }
            PoolEvent::Finished { request, .. } => {
                *terminals.entry(request.id).or_insert(0) += 1;
            }
            PoolEvent::Failed { request, code, .. } => {
                assert!(
                    ["engine_error", "bad_request", "cancelled",
                     "deadline", "overloaded"]
                        .contains(code),
                    "request {}: unknown code {code}",
                    request.id
                );
                *terminals.entry(request.id).or_insert(0) += 1;
            }
        }
    }
    for rid in &submitted {
        assert_eq!(
            terminals.get(rid),
            Some(&1),
            "request {rid}: expected exactly one terminal event"
        );
    }
    assert_eq!(terminals.len(), submitted.len());
}

#[test]
fn prop_blocked_kernels_equal_scalar_bitwise() {
    // THE kernel-refactor acceptance property: the blocked/tiled GEMM
    // kernels are bitwise-identical to the scalar loop nests across
    // random ragged shapes (panel remainders of every size), both
    // weight storage dtypes, and inputs salted with exact zeros (the
    // sparsity skip) and signed zeros.
    let mut rng = Rng::seed_from_u64(0xB10C);
    fn salted(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n)
            .map(|_| match rng.gen_range(0, 6) {
                0 => 0.0,
                1 => -0.0,
                _ => ((rng.gen_f64() - 0.5) * 8.0) as f32,
            })
            .collect()
    }
    fn bits(v: &[f32]) -> Vec<u32> {
        v.iter().map(|x| x.to_bits()).collect()
    }
    for case in 0..80 {
        let din = rng.gen_range(1, 70);
        let dout = rng.gen_range(1, 70);
        let x = salted(&mut rng, din);
        let w = salted(&mut rng, din * dout);
        let b = salted(&mut rng, dout);
        let w16: Vec<u16> =
            w.iter().map(|&v| F16::from_f32(v).to_bits()).collect();
        let b16: Vec<u16> =
            b.iter().map(|&v| F16::from_f32(v).to_bits()).collect();
        let mut s = vec![0.0f32; dout];
        let mut bl = vec![0.0f32; dout];
        for (wsl, bsl, dl) in [
            (WSlice::F32(&w), WSlice::F32(&b), "fp32"),
            (WSlice::F16(&w16), WSlice::F16(&b16), "fp16"),
        ] {
            linear(&x, wsl, bsl, din, dout, &mut s, Kernel::Scalar);
            linear(&x, wsl, bsl, din, dout, &mut bl, Kernel::Blocked);
            assert_eq!(
                bits(&s),
                bits(&bl),
                "case {case}/{dl}: linear {din}x{dout} diverged"
            );
        }
        // tied-embedding logits GEMV over its own ragged shapes
        let d = rng.gen_range(1, 40);
        let vocab = rng.gen_range(1, 70);
        let h = salted(&mut rng, d);
        let emb = salted(&mut rng, vocab * d);
        let emb16: Vec<u16> =
            emb.iter().map(|&v| F16::from_f32(v).to_bits()).collect();
        let mut s = vec![0.0f32; vocab];
        let mut bl = vec![0.0f32; vocab];
        for (esl, dl) in
            [(WSlice::F32(&emb), "fp32"), (WSlice::F16(&emb16), "fp16")]
        {
            logits_matvec(&h, esl, d, vocab, &mut s, Kernel::Scalar);
            logits_matvec(&h, esl, d, vocab, &mut bl, Kernel::Blocked);
            assert_eq!(
                bits(&s),
                bits(&bl),
                "case {case}/{dl}: logits {vocab}x{d} diverged"
            );
        }
    }
}

#[test]
fn prop_paged_fused_decode_equals_single_step() {
    // Fused multi-step greedy decode on the paged path is token-
    // identical to per-step dispatch across the FT rungs, both storage
    // dtypes, both kernel families and odd block geometries (the fused
    // step cap must always respect the block reservations).
    let mut rng = Rng::seed_from_u64(0xFD5E);
    for (dtype, kernel) in [
        (DType::F32, Kernel::Blocked),
        (DType::F16, Kernel::Blocked),
        (DType::F32, Kernel::Scalar),
    ] {
        let backend: Arc<dyn Backend> = {
            let mut b = RefBackend::synthetic();
            b.set_dtype(dtype);
            b.set_kernel(kernel);
            Arc::new(b)
        };
        let pruned_vocab =
            backend.manifest().config_for("pruned").vocab_size as u32;
        for kind in [EngineKind::FtFull, EngineKind::FtPruned] {
            for case in 0..4 {
                let kv = KvConfig {
                    paged: true,
                    block_size: [2, 16, 5, 3][case % 4],
                    blocks: 0,
                    ..KvConfig::default()
                };
                let fused = build_with_kv(
                    kind,
                    backend.clone(),
                    GenConfig::default(),
                    kv,
                )
                .unwrap();
                let single = build_with_kv(
                    kind,
                    backend.clone(),
                    GenConfig {
                        use_multi_step: false,
                        ..GenConfig::default()
                    },
                    kv,
                )
                .unwrap();
                let n = rng.gen_range(1, 6);
                let inputs = random_inputs(&mut rng, n, pruned_vocab);
                let a: Vec<Vec<u32>> = fused
                    .generate(&inputs, &mut Sampler::greedy())
                    .unwrap()
                    .into_iter()
                    .map(|o| o.generated)
                    .collect();
                let b: Vec<Vec<u32>> = single
                    .generate(&inputs, &mut Sampler::greedy())
                    .unwrap()
                    .into_iter()
                    .map(|o| o.generated)
                    .collect();
                assert_eq!(
                    a, b,
                    "{kind:?}/{dtype:?}/{kernel:?} case {case}: fused \
                     decode diverged from per-step"
                );
                assert!(
                    a.iter().map(|s| s.len()).sum::<usize>() > 0,
                    "{kind:?} case {case}: vacuous comparison"
                );
            }
        }
    }
}

#[test]
fn prop_prefix_shared_admissions_equal_solo_runs() {
    // THE prefix-sharing acceptance property: admissions whose prompts
    // adopt cached prefix blocks (refcounted, copy-on-write at the
    // divergence) must generate streams bitwise-identical to solo runs
    // on an engine with sharing disabled — across storage dtypes, both
    // kernel families, and odd block geometries.
    let mut rng = Rng::seed_from_u64(0x5A8E);
    for (dtype, kernel) in [
        (DType::F32, Kernel::Blocked),
        (DType::F16, Kernel::Blocked),
        (DType::F32, Kernel::Scalar),
        (DType::F16, Kernel::Scalar),
    ] {
        let backend: Arc<dyn Backend> = {
            let mut b = RefBackend::synthetic();
            b.set_dtype(dtype);
            b.set_kernel(kernel);
            Arc::new(b)
        };
        let pruned_vocab =
            backend.manifest().config_for("pruned").vocab_size as u32;
        for kind in [EngineKind::FtFull, EngineKind::FtPruned] {
            for case in 0..3 {
                let block_size = [3, 16, 5][case % 3];
                let shared = build_with_kv(
                    kind,
                    backend.clone(),
                    Default::default(),
                    KvConfig {
                        paged: true,
                        block_size,
                        blocks: 0,
                        prefix_share: true,
                    },
                )
                .unwrap();
                let solo = build_with_kv(
                    kind,
                    backend.clone(),
                    Default::default(),
                    KvConfig {
                        paged: true,
                        block_size,
                        blocks: 0,
                        prefix_share: false,
                    },
                )
                .unwrap();
                // one common word run spanning several full blocks,
                // then a unique tail per request — so every admission
                // after the first can adopt the shared blocks
                let stem: Vec<u32> = (0..2 * block_size + 3)
                    .map(|_| {
                        aigc_infer::special::FIRST_WORD
                            + rng.gen_range(0, (pruned_vocab - 4) as usize)
                                as u32
                    })
                    .collect();
                let mut inputs = Vec::new();
                for id in 0..4u64 {
                    let mut prompt = vec![aigc_infer::special::BOS];
                    prompt.extend_from_slice(&stem);
                    for _ in 0..rng.gen_range(1, 5) {
                        prompt.push(
                            aigc_infer::special::FIRST_WORD
                                + rng.gen_range(
                                    0,
                                    (pruned_vocab - 4) as usize,
                                ) as u32,
                        );
                    }
                    prompt.push(aigc_infer::special::SEP);
                    inputs.push(EngineInput {
                        request_id: id,
                        prompt,
                        max_new_tokens: rng.gen_range(2, 8),
                    });
                }
                let (wave1, wave2) = inputs.split_at(2);
                let mut sampler = Sampler::greedy();
                let mut session = shared.start(wave1).unwrap();
                let mut outputs: HashMap<u64, Vec<u32>> = HashMap::new();
                let mut drain =
                    |session: &mut Box<dyn DecodeSession>,
                     outputs: &mut HashMap<u64, Vec<u32>>| {
                        for f in session.take_finished() {
                            outputs.insert(
                                f.output.request_id,
                                f.output.generated,
                            );
                        }
                    };
                // decode a little, then a second wave arrives whose
                // prompts share the stem with the (indexed) first wave
                if session.active() > 0 {
                    session.step(&mut sampler).unwrap();
                }
                drain(&mut session, &mut outputs);
                assert!(
                    session.can_admit(wave2),
                    "{kind:?}/{dtype:?} case {case}: auto-sized pool \
                     must admit the second wave"
                );
                session.admit(wave2).unwrap();
                let stats = session
                    .prefix_stats()
                    .expect("sharing session must report prefix stats");
                assert!(
                    stats.hits >= 1,
                    "{kind:?}/{dtype:?}/{kernel:?} case {case}: no \
                     prefix hit on a shared-stem wave"
                );
                assert!(
                    stats.tokens_reused as usize >= block_size,
                    "{kind:?}/{dtype:?} case {case}: a hit must reuse \
                     at least one full block"
                );
                let mut guard = 0;
                while session.active() > 0 {
                    session.step(&mut sampler).unwrap();
                    drain(&mut session, &mut outputs);
                    guard += 1;
                    assert!(
                        guard < 1000,
                        "{kind:?} case {case}: no progress"
                    );
                }
                drain(&mut session, &mut outputs);
                // every stream must match a solo, non-sharing run of
                // just that request
                for input in &inputs {
                    let alone: Vec<u32> = solo
                        .generate(
                            std::slice::from_ref(input),
                            &mut Sampler::greedy(),
                        )
                        .unwrap()
                        .into_iter()
                        .next()
                        .unwrap()
                        .generated;
                    assert_eq!(
                        outputs[&input.request_id], alone,
                        "{kind:?}/{dtype:?}/{kernel:?} case {case}: \
                         request {} diverged from its solo run",
                        input.request_id
                    );
                    assert!(
                        !alone.is_empty(),
                        "{kind:?} case {case}: vacuous comparison"
                    );
                }
            }
        }
    }
}

#[test]
fn prop_pruned_streams_match_unpruned_on_kept_prefixes() {
    // THE runtime-pruning acceptance property: slicing the embedding /
    // logit matrices down to the kept set must be invisible to greedy
    // decoding wherever the full-vocab argmax lands inside the kept
    // set.  For every request, the pruned stream (mapped back to
    // original ids) must equal the unpruned stream up to the FIRST
    // unpruned token outside the kept set (past it the vocabularies
    // legitimately diverge — the pruned engine cannot emit a dropped
    // id).  Holds across storage dtypes, kernel families and both
    // cache disciplines, because the dense logits are bitwise equal to
    // the full logits at kept ids.
    use aigc_infer::config::PruneConfig;
    use aigc_infer::pruning::TokenRemap;

    let full_vocab = RefBackend::synthetic()
        .manifest()
        .config_for("full")
        .vocab_size;
    let remap = Arc::new(TokenRemap::derive(
        &PruneConfig { coverage: 0.9, ..PruneConfig::default() },
        full_vocab,
    ));
    let mut rng = Rng::seed_from_u64(0x9B0E);
    let mut compared = 0usize;
    for (dtype, kernel) in [
        (DType::F32, Kernel::Blocked),
        (DType::F16, Kernel::Blocked),
        (DType::F32, Kernel::Scalar),
    ] {
        let plain: Arc<dyn Backend> = {
            let mut b = RefBackend::synthetic();
            b.set_dtype(dtype);
            b.set_kernel(kernel);
            Arc::new(b)
        };
        let pruned: Arc<dyn Backend> = {
            let mut b = RefBackend::synthetic();
            b.set_pruning(remap.clone(), Default::default()).unwrap();
            b.set_dtype(dtype);
            b.set_kernel(kernel);
            Arc::new(b)
        };
        for kind in [EngineKind::FtFull, EngineKind::FtPruned] {
            let orig_vocab = plain
                .manifest()
                .config_for(kind.variant())
                .vocab_size;
            // prompts from the identity prefix: valid (and equal) in
            // BOTH id spaces — exactly what the resegmenting serving
            // boundary feeds a pruned engine
            let limit = remap.encode_limit(orig_vocab);
            for paged in [false, true] {
                let kv = KvConfig { paged, ..KvConfig::default() };
                let e_plain = build_with_kv(
                    kind,
                    plain.clone(),
                    Default::default(),
                    kv,
                )
                .unwrap();
                let e_pruned = build_with_kv(
                    kind,
                    pruned.clone(),
                    Default::default(),
                    kv,
                )
                .unwrap();
                let n = rng.gen_range(2, 8);
                let inputs = random_inputs(&mut rng, n, limit);
                let a: Vec<Vec<u32>> = e_plain
                    .generate(&inputs, &mut Sampler::greedy())
                    .unwrap()
                    .into_iter()
                    .map(|o| o.generated)
                    .collect();
                let b: Vec<Vec<u32>> = e_pruned
                    .generate(&inputs, &mut Sampler::greedy())
                    .unwrap()
                    .into_iter()
                    .map(|o| o.generated)
                    .collect();
                for (x, y) in a.iter().zip(&b) {
                    let mut mapped = y.clone();
                    remap.map_generated(&mut mapped);
                    let keep = x
                        .iter()
                        .take_while(|&&t| remap.to_dense(t).is_some())
                        .count();
                    if keep == x.len() {
                        assert_eq!(
                            &mapped, x,
                            "{kind:?}/{dtype:?}/{kernel:?} paged={paged}: \
                             fully-kept stream diverged"
                        );
                    } else {
                        assert!(
                            mapped.len() >= keep,
                            "{kind:?}/{dtype:?}/{kernel:?} paged={paged}: \
                             pruned stream shorter than the kept prefix"
                        );
                        assert_eq!(
                            &mapped[..keep],
                            &x[..keep],
                            "{kind:?}/{dtype:?}/{kernel:?} paged={paged}: \
                             kept prefix diverged"
                        );
                    }
                    compared += keep;
                }
            }
        }
    }
    assert!(compared > 0, "vacuous: no kept-prefix tokens compared");
}

#[test]
fn prop_speculative_streams_equal_greedy() {
    // THE self-speculative acceptance property: greedy paged decode
    // with n-gram drafting + fused verification (`speculate > 0`) is
    // bitwise-identical to plain greedy decode across the FT rungs,
    // both storage dtypes, both kernel families, odd block geometries
    // and chunked-vs-monolithic prefill.  Repetitive prompts guarantee
    // the drafter finds material, and the sweep-wide acceptance gate
    // keeps the property non-vacuous.
    let mut rng = Rng::seed_from_u64(0x59EC);
    let mut accepted_total = 0u64;
    for (dtype, kernel) in [
        (DType::F32, Kernel::Blocked),
        (DType::F16, Kernel::Blocked),
        (DType::F32, Kernel::Scalar),
    ] {
        let backend: Arc<dyn Backend> = {
            let mut b = RefBackend::synthetic();
            b.set_dtype(dtype);
            b.set_kernel(kernel);
            Arc::new(b)
        };
        let pruned_vocab =
            backend.manifest().config_for("pruned").vocab_size as u32;
        for kind in [EngineKind::FtFull, EngineKind::FtPruned] {
            for case in 0..4 {
                let kv = KvConfig {
                    paged: true,
                    block_size: [2, 16, 5, 3][case % 4],
                    blocks: 0,
                    ..KvConfig::default()
                };
                // chunked prefill on half the cases — drafting must
                // stay silent until a lane's prompt fully lands
                let chunk = if case % 2 == 0 { 0 } else { 3 };
                let spec = build_with_kv(
                    kind,
                    backend.clone(),
                    GenConfig {
                        speculate: 4,
                        prefill_chunk: chunk,
                        ..GenConfig::default()
                    },
                    kv,
                )
                .unwrap();
                let plain = build_with_kv(
                    kind,
                    backend.clone(),
                    GenConfig {
                        prefill_chunk: chunk,
                        ..GenConfig::default()
                    },
                    kv,
                )
                .unwrap();
                // short motifs repeated several times: the trailing
                // n-gram always has an earlier occurrence to extend
                let n = rng.gen_range(1, 5);
                let inputs: Vec<EngineInput> = (0..n)
                    .map(|i| {
                        let period = rng.gen_range(1, 4);
                        let motif: Vec<u32> = (0..period)
                            .map(|_| {
                                aigc_infer::special::FIRST_WORD
                                    + rng.gen_range(
                                        0,
                                        (pruned_vocab - 4) as usize,
                                    ) as u32
                            })
                            .collect();
                        let mut prompt = vec![aigc_infer::special::BOS];
                        for _ in 0..rng.gen_range(3, 7) {
                            prompt.extend_from_slice(&motif);
                        }
                        prompt.push(aigc_infer::special::SEP);
                        EngineInput {
                            request_id: i as u64,
                            prompt,
                            max_new_tokens: rng.gen_range(6, 16),
                        }
                    })
                    .collect();
                let want: Vec<Vec<u32>> = plain
                    .generate(&inputs, &mut Sampler::greedy())
                    .unwrap()
                    .into_iter()
                    .map(|o| o.generated)
                    .collect();
                // drive the speculative session by hand so acceptance
                // is observable through spec_stats()
                let mut sampler = Sampler::greedy();
                let mut session = spec.start(&inputs).unwrap();
                let mut outputs: Vec<Option<Vec<u32>>> =
                    vec![None; inputs.len()];
                let mut guard = 0;
                loop {
                    for f in session.take_finished() {
                        outputs[f.seq] = Some(f.output.generated);
                    }
                    if session.active() == 0 {
                        break;
                    }
                    session.step(&mut sampler).unwrap();
                    guard += 1;
                    assert!(
                        guard < 1000,
                        "{kind:?}/{dtype:?}/{kernel:?} case {case}: \
                         no progress"
                    );
                }
                let stats = session
                    .spec_stats()
                    .expect("speculating session must report stats");
                assert!(
                    stats.accepted <= stats.drafted,
                    "{kind:?}/{dtype:?} case {case}: accepted {} > \
                     drafted {}",
                    stats.accepted,
                    stats.drafted
                );
                assert_eq!(
                    stats.accepted, stats.dispatches_saved,
                    "{kind:?}/{dtype:?} case {case}: every accepted \
                     draft token skips exactly one dispatch"
                );
                accepted_total += stats.accepted;
                let got: Vec<Vec<u32>> =
                    outputs.into_iter().map(|o| o.unwrap()).collect();
                assert_eq!(
                    got, want,
                    "{kind:?}/{dtype:?}/{kernel:?} case {case} \
                     chunk={chunk}: speculative stream diverged from \
                     plain greedy"
                );
                assert!(
                    want.iter().map(|s| s.len()).sum::<usize>() > 0,
                    "{kind:?} case {case}: vacuous comparison"
                );
            }
        }
    }
    assert!(
        accepted_total > 0,
        "vacuous: no draft token was ever accepted across the sweep"
    );
}

#[test]
fn prop_zipf_prefix_mass_matches_empirical() {
    use aigc_infer::data::ZipfSampler;
    let z = ZipfSampler::new(2000, 1.1);
    let mut rng = Rng::seed_from_u64(0x31337);
    let mut counts = vec![0u32; 2000];
    let n = 50_000;
    for _ in 0..n {
        counts[z.sample(&mut rng)] += 1;
    }
    for prefix in [10usize, 100, 1000, 2000] {
        let emp: u32 = counts[..prefix].iter().sum();
        let emp = emp as f64 / n as f64;
        let ana = z.prefix_mass(prefix);
        assert!(
            (emp - ana).abs() < 0.02,
            "prefix {prefix}: empirical {emp} vs analytic {ana}"
        );
    }
}
