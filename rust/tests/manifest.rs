//! Manifest contract tests over the checked-in fixture
//! (`tests/fixtures/manifest.json`) — parse, validation, and the
//! strict-vs-lenient file requirements that separate the PJRT path
//! from the reference path.

use std::path::Path;

use aigc_infer::runtime::Manifest;
use aigc_infer::util::tmp::TempDir;
use aigc_infer::Error;

const FIXTURE_DIR: &str = "tests/fixtures";

fn fixture_text() -> String {
    std::fs::read_to_string(Path::new(FIXTURE_DIR).join("manifest.json"))
        .expect("fixture manifest present")
}

/// Write a patched copy of the fixture into a temp dir.
fn write_patched(from: &str, to: &str) -> TempDir {
    let dir = TempDir::new("manifest-fixture").unwrap();
    let original = fixture_text();
    let text = original.replace(from, to);
    assert_ne!(text, original, "patch '{from}' did not match the fixture");
    std::fs::write(dir.path().join("manifest.json"), &text).unwrap();
    dir
}

#[test]
fn fixture_parses_and_validates_leniently() {
    let m = Manifest::load_lenient(FIXTURE_DIR).unwrap();
    assert_eq!(m.version, 1);
    assert_eq!(m.artifacts.len(), 2);
    assert_eq!(m.multi_steps, 4);
    assert_eq!(m.batch_sizes, vec![1, 2]);
    assert_eq!(m.seq_lens, vec![4, 8]);
    // config/weights coverage and variant mapping
    assert_eq!(m.config_for("full").vocab_size, 16);
    assert_eq!(m.config_for("baseline").vocab_size, 16);
    assert_eq!(m.config_for("pruned").vocab_size, 8);
    assert_eq!(m.weights_key_for("baseline"), "full");
    assert_eq!(m.weights_key_for("pruned"), "pruned");
    assert_eq!(m.weights_entry("full").unwrap().params.len(), 1);
    // artifact lookup by name and by bucket
    assert!(m.find("baseline_fwd_b1_s4").is_some());
    assert!(m.find("missing").is_none());
    let e = m.select("ft_prefill", "pruned", 1, 3).unwrap();
    assert_eq!((e.batch, e.seq), (1, 4));
    // io roles decoded
    let a = m.find("ft_prefill_pruned_b1_s4").unwrap();
    assert_eq!(a.inputs.iter().filter(|i| i.role == "param").count(), 1);
    assert_eq!(a.inputs.iter().filter(|i| i.role == "data").count(), 2);
    assert_eq!(a.outputs.len(), 3);
}

#[test]
fn strict_load_requires_hlo_files() {
    // the fixture dir has no .hlo.txt files: strict load must name the
    // missing artifact instead of succeeding
    match Manifest::load(FIXTURE_DIR) {
        Err(Error::MissingArtifact(p)) => {
            assert!(p.ends_with(".hlo.txt"), "{p}")
        }
        other => panic!("expected MissingArtifact, got {other:?}"),
    }
}

#[test]
fn special_token_mismatch_rejected() {
    let dir = write_patched("\"pad\": 0", "\"pad\": 7");
    let err = Manifest::load_lenient(dir.path()).unwrap_err();
    assert!(
        err.to_string().contains("special token"),
        "unexpected error: {err}"
    );
}

#[test]
fn unsupported_version_rejected() {
    let dir = write_patched("\"version\": 1", "\"version\": 3");
    assert!(Manifest::load_lenient(dir.path()).is_err());
}

#[test]
fn param_count_mismatch_rejected() {
    // drop the baseline artifact's param input: 0 params declared vs 1
    // in weights[full]
    let dir = write_patched(
        r#"{"name": "tok_emb", "role": "param", "shape": [16, 4], "dtype": "f32"},"#,
        "",
    );
    let err = Manifest::load_lenient(dir.path()).unwrap_err();
    assert!(
        err.to_string().contains("param inputs"),
        "unexpected error: {err}"
    );
}

#[test]
fn missing_pruned_config_rejected() {
    let dir = write_patched("\"pruned\": {", "\"pruned_x\": {");
    assert!(Manifest::load_lenient(dir.path()).is_err());
}

#[test]
fn missing_manifest_gives_actionable_error() {
    let dir = TempDir::new("manifest-empty").unwrap();
    let err = Manifest::load_lenient(dir.path()).unwrap_err();
    assert!(err.to_string().contains("make artifacts"), "{err}");
}
