//! Integration tests over the hermetic reference backend: the full
//! L3 stack — manifest inventory, raw graph execution, engine
//! equivalence across the Table 1 ladder, pipeline modes, and the TCP
//! server — with no Python, no `xla` crate and no `artifacts/`
//! directory.
//!
//! The PJRT/real-artifact path lives in the feature-gated module at the
//! bottom (`--features pjrt -- --ignored`) instead of hard-failing when
//! artifacts are absent.

use std::io::{BufRead, BufReader, Write};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Instant;

use aigc_infer::config::{EngineKind, KvConfig, ServingConfig};
use aigc_infer::data::{CorpusConfig, Generator, TraceConfig, TraceGenerator};
use aigc_infer::engine::{
    build as build_engine, build_with_kv, DecodeSession, Engine,
    EngineInput, Sampler,
};
use aigc_infer::pipeline;
use aigc_infer::precision;
use aigc_infer::runtime::{
    backend_for, Backend, DType, DataArg, ExecOut, RefBackend,
};
use aigc_infer::special;
use aigc_infer::{Server, ServingEvent, SubmitOptions};

fn backend() -> Arc<dyn Backend> {
    Arc::new(RefBackend::synthetic())
}

fn cfg(engine: EngineKind, pipelined: bool) -> ServingConfig {
    let mut c = ServingConfig::default();
    c.engine = engine;
    c.pipelined = pipelined;
    c.gen.max_new_tokens = 8;
    c
}

fn workload(n: usize, seed: u64) -> Vec<aigc_infer::data::Request> {
    let mut t = TraceGenerator::new(
        TraceConfig { max_new_tokens: 8, ..Default::default() },
        seed,
    );
    t.take(n)
}

/// Seeded prompts `[BOS] doc… [SEP]`, optionally restricted to ids
/// below `vocab_cap` (the pruned-vocab scenario).
fn seeded_prompts(
    n: usize,
    seed: u64,
    max_new: usize,
    vocab_cap: Option<u32>,
) -> Vec<EngineInput> {
    let mut gen = Generator::new(CorpusConfig::default(), seed);
    (0..n)
        .map(|i| {
            let d = gen.generate_capped(20);
            let mut prompt = vec![special::BOS];
            match vocab_cap {
                Some(cap) => prompt.extend(
                    d.doc_tokens.iter().copied().filter(|&t| t < cap),
                ),
                None => prompt.extend_from_slice(&d.doc_tokens),
            }
            prompt.push(special::SEP);
            EngineInput {
                request_id: i as u64,
                prompt,
                max_new_tokens: max_new,
            }
        })
        .collect()
}

/// Generate for many prompts through an engine in bucket-sized chunks.
fn generate_all(
    engine: &dyn Engine,
    inputs: &[EngineInput],
    chunk: usize,
) -> Vec<Vec<u32>> {
    let mut out = Vec::with_capacity(inputs.len());
    for batch in inputs.chunks(chunk) {
        let outs = engine.generate(batch, &mut Sampler::greedy()).unwrap();
        out.extend(outs.into_iter().map(|o| o.generated));
    }
    out
}

#[test]
fn default_backend_inventory_is_complete() {
    let b = backend_for(&ServingConfig::default()).unwrap();
    assert_eq!(b.name(), "reference");
    let m = b.manifest();
    assert_eq!(m.version, 1);
    for kind in ["baseline_fwd", "ft_prefill", "ft_decode", "ft_decode_multi"]
    {
        assert!(
            m.artifacts.iter().any(|a| a.kind == kind),
            "missing kind {kind}"
        );
    }
    // pruned config is actually pruned
    let full = m.config_for("full");
    let pruned = m.config_for("pruned");
    assert!(pruned.vocab_size < full.vocab_size);
    assert!(pruned.max_position < full.max_position);
}

#[test]
fn raw_graph_execution_shapes() {
    let b = backend();
    let m = b.manifest();
    let entry = m.select("ft_prefill", "full", 1, 32).unwrap();
    assert_eq!((entry.batch, entry.seq), (1, 32));
    let name = entry.name.clone();
    let vocab = m.config_for("full").vocab_size;
    let tokens: Vec<i32> = {
        let mut t = vec![special::PAD as i32; 32];
        t[0] = special::BOS as i32;
        for (i, slot) in t.iter_mut().enumerate().take(9).skip(1) {
            *slot = (special::FIRST_WORD + i as u32) as i32;
        }
        t[9] = special::SEP as i32;
        t
    };
    let outs = b
        .execute(
            &name,
            vec![
                DataArg::I32(tokens, vec![1, 32]),
                DataArg::I32(vec![10], vec![1]),
            ],
        )
        .unwrap();
    assert_eq!(outs.len(), 3); // logits + k_cache + v_cache
    let logits = outs.into_iter().next().unwrap().into_f32().unwrap();
    assert_eq!(logits.len(), vocab);
    assert!(logits.iter().all(|v| v.is_finite()));
    assert!(b.stats().executions >= 1);
}

#[test]
fn bucket_selection_prefers_cheapest() {
    let b = backend();
    let m = b.manifest();
    let e = m.select("ft_prefill", "full", 2, 40).unwrap();
    assert_eq!((e.batch, e.seq), (4, 64));
    let e = m.select("baseline_fwd", "baseline", 1, 1).unwrap();
    assert_eq!((e.batch, e.seq), (1, 32));
    assert!(m.select("ft_prefill", "full", 9, 32).is_err());
    assert!(m.select("ft_prefill", "pruned", 1, 512).is_err());
}

#[test]
fn ft_matches_baseline_greedy_tokens() {
    // Acceptance criterion: the FT engine (KV cache + fused prefill/
    // decode) must generate IDENTICAL greedy tokens to the naive
    // full-recompute baseline on the reference backend, for >= 16
    // seeded prompts — the optimizations change speed, not answers (§4).
    let b = backend();
    let baseline =
        build_engine(EngineKind::Baseline, b.clone(), Default::default())
            .unwrap();
    let ft = build_engine(EngineKind::FtFull, b.clone(), Default::default())
        .unwrap();
    let inputs = seeded_prompts(16, 11, 8, None);
    let a = generate_all(baseline.as_ref(), &inputs, 4);
    let c = generate_all(ft.as_ref(), &inputs, 4);
    for (i, (x, y)) in a.iter().zip(&c).enumerate() {
        assert_eq!(x, y, "prompt {i}: baseline vs ft_full diverged");
    }
    assert!(
        a.iter().map(|g| g.len()).sum::<usize>() > 0,
        "no tokens generated at all"
    );
}

#[test]
fn pruned_engine_matches_full_on_pruned_vocab_prompts() {
    // Acceptance criterion: on prompts made only of retained (pruned-
    // prefix) ids, the pruned engine matches the full engine for as
    // long as the full engine's own greedy choices stay inside the
    // retained vocabulary (pruning only removes logit rows).
    let b = backend();
    let pruned_vocab = b.manifest().config_for("pruned").vocab_size as u32;
    let full = build_engine(EngineKind::FtFull, b.clone(), Default::default())
        .unwrap();
    let pruned =
        build_engine(EngineKind::FtPruned, b.clone(), Default::default())
            .unwrap();
    let inputs = seeded_prompts(16, 23, 8, Some(pruned_vocab));
    let a = generate_all(full.as_ref(), &inputs, 4);
    let c = generate_all(pruned.as_ref(), &inputs, 4);
    let mut compared = 0usize;
    for (i, (x, y)) in a.iter().zip(&c).enumerate() {
        // compare up to the first full-engine token outside the prefix
        let cut = x
            .iter()
            .position(|&t| t >= pruned_vocab)
            .unwrap_or(x.len());
        assert_eq!(
            &x[..cut],
            &y[..cut.min(y.len())],
            "prompt {i}: pruned diverged inside retained vocab"
        );
        compared += cut;
    }
    assert!(compared > 0, "pruned comparison was vacuous");
}

#[test]
fn multi_step_equals_single_step() {
    // Same graphs, same dtype, both greedy: identical tokens.  Runs on
    // the contiguous cache discipline — the fused multi-step decode
    // executable is a contiguous-path feature (the paged session
    // decodes one step per call, batching rows per call instead).
    let b = backend();
    let legacy = KvConfig { paged: false, ..KvConfig::default() };
    let multi = build_with_kv(
        EngineKind::FtPruned,
        b.clone(),
        aigc_infer::config::GenConfig { max_new_tokens: 12, use_multi_step: true },
        legacy,
    )
    .unwrap();
    let single = build_with_kv(
        EngineKind::FtPruned,
        b.clone(),
        aigc_infer::config::GenConfig {
            max_new_tokens: 12,
            use_multi_step: false,
        },
        legacy,
    )
    .unwrap();
    let inputs = seeded_prompts(3, 22, 12, None);
    let a = multi.generate(&inputs, &mut Sampler::greedy()).unwrap();
    let c = single.generate(&inputs, &mut Sampler::greedy()).unwrap();
    for (x, y) in a.iter().zip(&c) {
        assert_eq!(x.generated, y.generated);
    }
}

#[test]
fn top_k_sampling_generates_valid_ids() {
    let b = backend();
    let vocab = b.manifest().config_for("pruned").vocab_size as u32;
    let ft = build_engine(EngineKind::FtPruned, b, Default::default())
        .unwrap();
    let inputs = seeded_prompts(2, 44, 6, None);
    let outs = ft
        .generate(&inputs, &mut Sampler::top_k(8, 0.9, 123))
        .unwrap();
    for o in outs {
        for &t in &o.generated {
            assert!(t < vocab);
            assert_ne!(t, special::EOS);
        }
    }
}

#[test]
fn pipelined_equals_sequential_results() {
    // Greedy decoding on the reference backend is deterministic and
    // per-request results are independent of batch composition, so the
    // two executors must agree exactly.
    let reqs = workload(12, 55);
    let seq = pipeline::run(&cfg(EngineKind::FtPruned, false), &reqs)
        .unwrap();
    let par = pipeline::run(&cfg(EngineKind::FtPruned, true), &reqs)
        .unwrap();
    assert_eq!(seq.responses.len(), reqs.len());
    assert_eq!(par.responses.len(), reqs.len());
    let mut a: Vec<_> = seq
        .responses
        .iter()
        .map(|r| (r.id, r.summary_ids.clone()))
        .collect();
    let mut b: Vec<_> = par
        .responses
        .iter()
        .map(|r| (r.id, r.summary_ids.clone()))
        .collect();
    a.sort();
    b.sort();
    assert_eq!(a, b);
    assert!(seq.runtime_stats.executions > 0);
}

/// Sorted (id, tokens) pairs for order-independent comparison.
fn response_set(s: &pipeline::RunSummary) -> Vec<(u64, Vec<u32>)> {
    let mut v: Vec<_> = s
        .responses
        .iter()
        .map(|r| (r.id, r.summary_ids.clone()))
        .collect();
    v.sort();
    v
}

#[test]
fn one_worker_pool_matches_sequential_across_full_ladder() {
    // Acceptance criterion: with --workers 1 the pooled pipelined
    // executor produces output tokens identical to the pre-refactor
    // (sequential) path, for EVERY Table 1 ladder row.
    let reqs = workload(12, 41);
    for engine in
        [EngineKind::Baseline, EngineKind::FtFull, EngineKind::FtPruned]
    {
        let seq = pipeline::run(&cfg(engine, false), &reqs).unwrap();
        let mut pooled_cfg = cfg(engine, true);
        pooled_cfg.workers = 1;
        let pooled = pipeline::run(&pooled_cfg, &reqs).unwrap();
        assert_eq!(
            response_set(&seq),
            response_set(&pooled),
            "{engine:?}: workers=1 pool diverged from sequential"
        );
        assert_eq!(pooled.workers, 1);
    }
}

#[test]
fn two_worker_pool_matches_one_worker_token_sets() {
    // Determinism across pool sizes: same trace, same seeds -> the SAME
    // SET of (id, tokens), only completion order may differ.
    let reqs = workload(16, 99);
    let mut one = cfg(EngineKind::FtPruned, true);
    one.workers = 1;
    let mut two = cfg(EngineKind::FtPruned, true);
    two.workers = 2;
    let a = pipeline::run(&one, &reqs).unwrap();
    let b = pipeline::run(&two, &reqs).unwrap();
    assert_eq!(a.responses.len(), reqs.len());
    assert_eq!(b.responses.len(), reqs.len());
    assert_eq!(response_set(&a), response_set(&b));
    assert_eq!(b.workers, 2);
    // per-worker metrics merged back into one summary: every decode
    // session is at least one backend execution (prefill), usually
    // more (decode steps)
    assert!(b.session_latency.count() > 0);
    assert!(
        b.runtime_stats.executions as u64 >= b.session_latency.count(),
        "executions {} < sessions {}",
        b.runtime_stats.executions,
        b.session_latency.count()
    );
}

#[test]
fn unservable_request_rejected_at_boundary_not_deadlock() {
    use aigc_infer::server::StreamingPipeline;
    use std::time::Duration;

    let mut scfg = cfg(EngineKind::FtPruned, true);
    scfg.batch.max_wait_ms = 5;
    let pipeline = StreamingPipeline::start(scfg).unwrap();
    let handle = pipeline.handle();
    let request = |id: u64, max_new: usize| aigc_infer::data::Request {
        id,
        text: "ba gedu".into(),
        max_new_tokens: max_new,
        arrival: Duration::ZERO,
        reference_summary: None,
    };

    // max_new_tokens far beyond every compiled bucket: rejected AT THE
    // BOUNDARY with a typed bad_request — it never poisons a batch.
    let err = handle
        .submit(request(1, 100_000), SubmitOptions::default())
        .expect_err("unservable budget must be rejected at submit");
    assert_eq!(err.code(), "bad_request");
    assert!(err.to_string().contains("max_seq"), "{err}");
    let err = handle
        .submit(request(1, 0), SubmitOptions::default())
        .expect_err("zero budget must be rejected at submit");
    assert_eq!(err.code(), "bad_request");

    // an oversized PROMPT passes submit (tokenization happens in the
    // pre stage) but gets a typed terminal error event, not a hang
    let words: Vec<String> = (0..300)
        .map(|i| aigc_infer::tokenizer::vocab::render_rank(i % 2000))
        .collect();
    let stream = handle
        .submit(
            aigc_infer::data::Request {
                id: 0,
                text: words.join(" "),
                max_new_tokens: 16,
                arrival: Duration::ZERO,
                reference_summary: None,
            },
            SubmitOptions::default(),
        )
        .expect("prompt-length rejection is asynchronous");
    let resp = stream.wait().expect("terminal event, not a hang");
    let err = resp.error.expect("oversized prompt must error");
    assert!(err.contains("max_seq"), "{err}");
    assert_eq!(resp.code, Some("bad_request"));

    // the pipeline keeps serving after rejections
    let resp = handle
        .submit(request(2, 4), SubmitOptions::default())
        .unwrap()
        .wait()
        .expect("pipeline must survive rejected requests");
    assert!(resp.error.is_none(), "{:?}", resp.error);
}

#[test]
fn embed_server_streams_tokens_before_done() {
    let server = Server::builder()
        .engine(EngineKind::FtPruned)
        .max_new_tokens(12)
        .start()
        .unwrap();
    let mut gen = Generator::new(CorpusConfig::default(), 21);
    let d = gen.generate_capped(16);
    let stream = server.submit(d.text, 12).unwrap();
    let mut streamed_ids: Vec<u32> = Vec::new();
    let mut streamed_text: Vec<String> = Vec::new();
    let mut done: Option<aigc_infer::coordinator::ServingResponse> = None;
    for ev in stream.iter() {
        match ev {
            ServingEvent::Token { tokens, text } => {
                assert!(done.is_none(), "token event after done");
                streamed_ids.extend(tokens);
                streamed_text.push(text);
            }
            ServingEvent::Done(resp) => done = Some(resp),
        }
    }
    let resp = done.expect("terminal event");
    assert!(resp.error.is_none(), "{:?}", resp.error);
    assert_eq!(
        streamed_ids, resp.summary_ids,
        "streamed tokens must equal the final summary ids"
    );
    if !resp.summary_ids.is_empty() {
        assert!(
            !streamed_text.is_empty(),
            "tokens must stream before done"
        );
        // specials render as "": the summary is the non-empty chunks
        let joined = streamed_text
            .iter()
            .filter(|t| !t.is_empty())
            .cloned()
            .collect::<Vec<_>>()
            .join(" ");
        assert_eq!(joined, resp.summary_text);
        assert!(resp.ttft.is_some(), "ttft measured for streamed request");
        assert!(resp.ttft.unwrap() <= resp.latency);
    }
    assert!(resp.steps > 0, "steps-per-retire must be threaded through");
}

#[test]
fn deadline_expired_request_gets_terminal_error_event() {
    use std::time::Duration;
    let server = Server::builder()
        .engine(EngineKind::FtPruned)
        .max_new_tokens(16)
        .start()
        .unwrap();
    let stream = server
        .submit_request(
            aigc_infer::data::Request {
                id: 0,
                text: "ba gedu fi".into(),
                max_new_tokens: 16,
                arrival: Duration::ZERO,
                reference_summary: None,
            },
            SubmitOptions { deadline: Some(Duration::ZERO) },
        )
        .unwrap();
    // an already-expired deadline is caught at the FIRST step boundary:
    // terminal error event, zero tokens, no hang
    let resp = stream.wait().expect("terminal event, not a hang");
    assert_eq!(resp.code, Some("deadline"), "{:?}", resp.error);
    assert!(resp.summary_ids.is_empty());
}

#[test]
fn cancelled_request_gets_terminal_error_event() {
    let server = Server::builder()
        .engine(EngineKind::FtPruned)
        .max_new_tokens(64)
        .start()
        .unwrap();
    // cancel before the batcher's 20ms flush window elapses, so the
    // flag is observed at the session's first step boundary
    let stream = server.submit("ba gedu fi do", 64).unwrap();
    stream.cancel();
    let mut terminal = None;
    for ev in stream.iter() {
        if let ServingEvent::Done(resp) = ev {
            terminal = Some(resp);
        }
    }
    let resp = terminal.expect("terminal event, not a hang");
    assert_eq!(resp.code, Some("cancelled"), "{:?}", resp.error);
}

#[test]
fn server_under_cache_pressure_serves_every_request() {
    // End-to-end cache-pressure: a starved paged pool forces requests
    // to queue on KV capacity inside the continuous batcher; every
    // submission still gets exactly one successful terminal event, and
    // replies carry the pool occupancy snapshot.
    let server = Server::builder()
        .engine(EngineKind::FtPruned)
        .max_new_tokens(6)
        .kv_block_size(4)
        .kv_blocks(16) // 64 slots: any one request fits, the batch can't
        .start()
        .unwrap();
    let mut gen = Generator::new(CorpusConfig::default(), 33);
    let streams: Vec<_> = (0..8)
        .map(|_| {
            let d = gen.generate_capped(8);
            server.submit(d.text, 6).unwrap()
        })
        .collect();
    for s in streams {
        let resp = s.wait().expect("terminal event");
        assert!(resp.error.is_none(), "{:?}", resp.error);
        let (used, total) =
            resp.kv_blocks.expect("paged server reports occupancy");
        assert_eq!(total, 16);
        assert!(used <= total, "pool overcommitted: {used}/{total}");
    }
}

#[test]
fn admission_split_matches_one_shot_generate() {
    // Continuous-batching token identity at the engine level: starting
    // half the batch, stepping, then admitting the rest produces the
    // same per-request greedy tokens as one-shot generation — on BOTH
    // cache disciplines (paged block pools and legacy contiguous
    // buckets; the baseline engine has no cache either way).
    let b = backend();
    for paged in [true, false] {
        let kv = KvConfig { paged, ..KvConfig::default() };
        for kind in
            [EngineKind::Baseline, EngineKind::FtFull, EngineKind::FtPruned]
        {
            let engine =
                build_with_kv(kind, b.clone(), Default::default(), kv)
                    .unwrap();
            let inputs = seeded_prompts(6, 77, 8, None);
            let one_shot: Vec<Vec<u32>> = engine
                .generate(&inputs, &mut Sampler::greedy())
                .unwrap()
                .into_iter()
                .map(|o| o.generated)
                .collect();

            let (first, rest) = inputs.split_at(3);
            let mut sampler = Sampler::greedy();
            let mut session = engine.start(first).unwrap();
            session.step(&mut sampler).unwrap();
            session.step(&mut sampler).unwrap();
            assert!(
                session.can_admit(rest),
                "{kind:?} paged={paged}: admission must fit"
            );
            session.admit(rest).unwrap();
            let mut outs: Vec<Option<Vec<u32>>> = vec![None; inputs.len()];
            loop {
                for f in session.take_finished() {
                    outs[f.seq] = Some(f.output.generated);
                }
                if session.active() == 0 {
                    break;
                }
                session.step(&mut sampler).unwrap();
            }
            let split: Vec<Vec<u32>> =
                outs.into_iter().map(|o| o.unwrap()).collect();
            assert_eq!(
                one_shot, split,
                "{kind:?} paged={paged}: admission changed greedy streams"
            );
        }
    }
}

#[test]
fn paged_admission_prefills_only_the_new_row() {
    // THE acceptance criterion of the paged refactor: admitting into a
    // live session costs the NEW row's prompt, while the legacy
    // contiguous path re-prefills every live row's grown context.
    let b = backend();
    let inputs = seeded_prompts(4, 31, 8, None);
    let (first, rest) = inputs.split_at(3);
    let run = |paged: bool| -> (u64, u64) {
        let engine = build_with_kv(
            EngineKind::FtPruned,
            b.clone(),
            Default::default(),
            // sharing off: this test pins the PR-5 accounting (admission
            // prefills exactly the new prompt); with the prefix index on,
            // even the shared BOS would shave a token off via a COW tail
            KvConfig { paged, prefix_share: false, ..KvConfig::default() },
        )
        .unwrap();
        let mut session = engine.start(first).unwrap();
        let seed_cost = session.prefill_tokens();
        // admit before any step: every seed row is deterministically
        // still live, so the legacy re-prefill cost is exact
        session.admit(rest).unwrap();
        (seed_cost, session.prefill_tokens() - seed_cost)
    };
    let seed_prompts: u64 =
        first.iter().map(|i| i.prompt.len() as u64).sum();
    let new_prompt = rest[0].prompt.len() as u64;

    let (paged_seed, paged_admit) = run(true);
    assert_eq!(paged_seed, seed_prompts, "paged seed = its prompts");
    assert_eq!(
        paged_admit, new_prompt,
        "paged admission must prefill ONLY the new row"
    );

    let (legacy_seed, legacy_admit) = run(false);
    assert_eq!(legacy_seed, seed_prompts);
    assert_eq!(
        legacy_admit,
        seed_prompts + new_prompt,
        "legacy admission re-prefills the whole batch"
    );
    assert!(legacy_admit > paged_admit);
}

#[test]
fn paged_session_frees_blocks_at_retirement() {
    // Retirement returns capacity immediately: cancel one of two live
    // rows and the pool's free-block count rises before the session
    // ends.
    let b = backend();
    let engine = build_with_kv(
        EngineKind::FtPruned,
        b,
        Default::default(),
        KvConfig { paged: true, block_size: 4, blocks: 32, ..KvConfig::default() },
    )
    .unwrap();
    let inputs = seeded_prompts(2, 91, 8, None);
    let mut sampler = Sampler::greedy();
    let mut session = engine.start(&inputs).unwrap();
    session.step(&mut sampler).unwrap();
    let before = session.kv_stats().expect("paged session reports stats");
    assert!(before.used_blocks() > 0);
    // cancel whichever row is still live (a first-step EOS would have
    // retired — and freed — a row already)
    let retired = inputs.iter().any(|i| {
        session.retire(
            i.request_id,
            aigc_infer::engine::FinishReason::Cancelled,
        )
    });
    assert!(retired, "no live row left to cancel");
    let after = session.kv_stats().unwrap();
    assert!(
        after.free_blocks > before.free_blocks,
        "retirement must free the row's blocks immediately \
         ({} -> {} free)",
        before.free_blocks,
        after.free_blocks
    );
    // the freed capacity is immediately admissible again
    let extra = seeded_prompts(1, 92, 8, None);
    assert!(session.can_admit(&extra));
}

#[test]
fn run_summary_threads_ttft_and_steps() {
    let reqs = workload(8, 13);
    for pipelined in [false, true] {
        let s = pipeline::run(&cfg(EngineKind::FtPruned, pipelined), &reqs)
            .unwrap();
        assert_eq!(s.responses.len(), reqs.len());
        let with_tokens = s
            .responses
            .iter()
            .filter(|r| !r.summary_ids.is_empty())
            .count() as u64;
        assert_eq!(s.ttft.count(), with_tokens, "pipelined={pipelined}");
        assert!(s.steps_per_retire >= 1.0, "pipelined={pipelined}");
        for r in &s.responses {
            assert!(r.steps > 0);
            if !r.summary_ids.is_empty() {
                let t = r.ttft.expect("response with tokens has a ttft");
                assert!(t <= r.latency);
            }
        }
    }
}

#[test]
fn full_ladder_runs_end_to_end() {
    // All four Table 1 rows complete on the hermetic backend and return
    // every request.
    let reqs = workload(6, 77);
    for (engine, pipelined) in [
        (EngineKind::Baseline, false),
        (EngineKind::FtFull, false),
        (EngineKind::FtPruned, false),
        (EngineKind::FtPruned, true),
    ] {
        let s = pipeline::run(&cfg(engine, pipelined), &reqs)
            .unwrap_or_else(|e| panic!("{engine:?}/{pipelined}: {e}"));
        assert_eq!(s.responses.len(), reqs.len(), "{engine:?}");
    }
}

#[test]
fn server_round_trip() {
    let addr = "127.0.0.1:17171";
    let shutdown = Arc::new(AtomicBool::new(false));
    let sd = shutdown.clone();
    let mut scfg = cfg(EngineKind::FtPruned, true);
    scfg.batch.max_wait_ms = 5;
    let server = std::thread::spawn(move || {
        let _ = aigc_infer::server::serve(scfg, addr, sd);
    });
    // wait for the listener
    let mut stream = None;
    let deadline = Instant::now() + std::time::Duration::from_secs(30);
    while Instant::now() < deadline {
        match std::net::TcpStream::connect(addr) {
            Ok(s) => {
                stream = Some(s);
                break;
            }
            Err(_) => std::thread::sleep(std::time::Duration::from_millis(50)),
        }
    }
    let stream = stream.expect("server did not come up");
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut writer = stream;

    let mut gen = Generator::new(CorpusConfig::default(), 66);
    for i in 0..3 {
        let d = gen.generate_capped(16);
        writeln!(
            writer,
            "{{\"id\": {i}, \"text\": \"{}\", \"max_new_tokens\": 4}}",
            d.text
        )
        .unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let v = aigc_infer::util::json::parse(&line).unwrap();
        assert_eq!(v.get("id").as_u64(), Some(i));
        assert!(v.get("summary").as_str().is_some());
        assert!(v.get("latency_ms").as_f64().unwrap() > 0.0);
    }
    // malformed line gets a coded error object, not a hang
    writeln!(writer, "{{\"nope\": 1}}").unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("error"));
    assert!(line.contains("bad_request"), "{line}");

    // a request WITHOUT a client id gets the server-assigned id echoed
    writeln!(writer, "{{\"text\": \"ba\", \"max_new_tokens\": 4}}")
        .unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    let v = aigc_infer::util::json::parse(&line).unwrap();
    assert!(
        v.get("id").as_u64().is_some(),
        "absent client id must still be echoed uniquely: {line}"
    );

    shutdown.store(true, Ordering::Relaxed);
    drop(writer);
    drop(reader);
    let _ = server.join();
}

#[test]
fn server_v2_streams_token_events_then_done() {
    let addr = "127.0.0.1:17175";
    let shutdown = Arc::new(AtomicBool::new(false));
    let sd = shutdown.clone();
    let mut scfg = cfg(EngineKind::FtPruned, true);
    scfg.batch.max_wait_ms = 5;
    scfg.gen.max_new_tokens = 12;
    let server = std::thread::spawn(move || {
        let _ = aigc_infer::server::serve(scfg, addr, sd);
    });
    let deadline = Instant::now() + std::time::Duration::from_secs(30);
    let stream = loop {
        match std::net::TcpStream::connect(addr) {
            Ok(s) => break s,
            Err(e) if Instant::now() >= deadline => {
                panic!("server did not come up: {e}")
            }
            Err(_) => {
                std::thread::sleep(std::time::Duration::from_millis(50))
            }
        }
    };
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut writer = stream;

    let mut gen = Generator::new(CorpusConfig::default(), 55);
    let d = gen.generate_capped(16);
    writeln!(
        writer,
        "{{\"v\": 2, \"id\": 42, \"text\": \"{}\", \"max_new_tokens\": 12}}",
        d.text
    )
    .unwrap();
    let mut token_lines = 0usize;
    let mut streamed: Vec<String> = Vec::new();
    let terminal = loop {
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let v = aigc_infer::util::json::parse(&line).unwrap();
        assert_eq!(v.get("id").as_u64(), Some(42), "{line}");
        match v.get("event").as_str() {
            Some("token") => {
                token_lines += 1;
                if let Some(t) = v.get("token_text").as_str() {
                    if !t.is_empty() {
                        streamed.push(t.to_string());
                    }
                }
            }
            Some("done") | Some("error") => break v,
            other => panic!("unexpected event {other:?}: {line}"),
        }
    };
    assert_eq!(terminal.get("event").as_str(), Some("done"));
    let summary = terminal.get("summary").as_str().unwrap().to_string();
    let n_tokens = terminal.get("n_tokens").as_usize().unwrap();
    if n_tokens > 0 {
        assert!(token_lines > 0, "token events must precede done");
        assert_eq!(streamed.join(" "), summary);
        assert!(
            terminal.get("ttft_ms").as_f64().is_some(),
            "v2 done line reports ttft"
        );
    }

    shutdown.store(true, Ordering::Relaxed);
    drop(writer);
    drop(reader);
    let _ = server.join();
}

#[test]
fn server_round_trip_multi_worker() {
    // The streaming TCP server over a 2-worker inference pool, driven
    // by concurrent clients; every request gets exactly one reply, and
    // an unservable request gets an error reply on the right id.
    let addr = "127.0.0.1:17173";
    let shutdown = Arc::new(AtomicBool::new(false));
    let sd = shutdown.clone();
    let mut scfg = cfg(EngineKind::FtPruned, true);
    scfg.workers = 2;
    scfg.batch.max_wait_ms = 5;
    let server = std::thread::spawn(move || {
        let _ = aigc_infer::server::serve(scfg, addr, sd);
    });
    let deadline = Instant::now() + std::time::Duration::from_secs(30);
    let connect = || loop {
        match std::net::TcpStream::connect(addr) {
            Ok(s) => return s,
            Err(_) if Instant::now() < deadline => {
                std::thread::sleep(std::time::Duration::from_millis(50))
            }
            Err(e) => panic!("server did not come up: {e}"),
        }
    };
    let _probe = connect(); // wait for the listener before spawning clients

    let clients: Vec<_> = (0..2)
        .map(|c| {
            std::thread::spawn(move || {
                let stream = loop {
                    match std::net::TcpStream::connect(addr) {
                        Ok(s) => break s,
                        Err(_) => std::thread::sleep(
                            std::time::Duration::from_millis(50),
                        ),
                    }
                };
                let mut reader =
                    BufReader::new(stream.try_clone().unwrap());
                let mut writer = stream;
                let mut gen =
                    Generator::new(CorpusConfig::default(), 100 + c);
                for i in 0..4u64 {
                    let d = gen.generate_capped(16);
                    writeln!(
                        writer,
                        "{{\"id\": {i}, \"text\": \"{}\", \
                         \"max_new_tokens\": 4}}",
                        d.text
                    )
                    .unwrap();
                    let mut line = String::new();
                    reader.read_line(&mut line).unwrap();
                    let v = aigc_infer::util::json::parse(&line).unwrap();
                    assert_eq!(v.get("id").as_u64(), Some(i), "{line}");
                    assert!(v.get("summary").as_str().is_some(), "{line}");
                }
                // unservable request: typed error reply on the right
                // id — rejected at the boundary, no hang
                writeln!(
                    writer,
                    "{{\"id\": 77, \"text\": \"ba\", \
                     \"max_new_tokens\": 100000}}"
                )
                .unwrap();
                let mut line = String::new();
                reader.read_line(&mut line).unwrap();
                let v = aigc_infer::util::json::parse(&line).unwrap();
                assert_eq!(v.get("id").as_u64(), Some(77), "{line}");
                assert!(v.get("error").as_str().is_some(), "{line}");
                assert_eq!(
                    v.get("code").as_str(),
                    Some("bad_request"),
                    "{line}"
                );
            })
        })
        .collect();
    for c in clients {
        c.join().expect("client thread failed");
    }
    shutdown.store(true, Ordering::Relaxed);
    let _ = server.join();
}

// ------------------------------------------------------- fp16 precision

#[test]
fn fp16_ladder_runs_end_to_end_and_reports_dtype() {
    // --dtype fp16 across every Table-1 rung (offline executors): all
    // requests complete, and the precision is reported per run AND per
    // response so fp16 numbers are never mistaken for fp32 ones.
    let reqs = workload(6, 77);
    for (engine, pipelined) in [
        (EngineKind::Baseline, false),
        (EngineKind::FtFull, false),
        (EngineKind::FtPruned, false),
        (EngineKind::FtPruned, true),
    ] {
        let mut c = cfg(engine, pipelined);
        c.dtype = DType::F16;
        let s = pipeline::run(&c, &reqs)
            .unwrap_or_else(|e| panic!("{engine:?}/{pipelined}: {e}"));
        assert_eq!(s.responses.len(), reqs.len(), "{engine:?}");
        assert_eq!(s.dtype, DType::F16);
        for r in &s.responses {
            assert_eq!(r.dtype, Some("fp16"), "{engine:?}");
        }
    }
    // and the fp32 path reports fp32
    let s = pipeline::run(&cfg(EngineKind::FtPruned, false), &reqs)
        .unwrap();
    assert_eq!(s.dtype, DType::F32);
    assert!(s.responses.iter().all(|r| r.dtype == Some("fp32")));
}

#[test]
fn fp16_greedy_streams_match_fp32_on_probe_prompts() {
    // THE accuracy gate (paper §4 "maintaining high levels of
    // performance"): on the synthetic model, fp16 greedy decoding must
    // agree with the fp32 reference token-for-token, with logit
    // divergence at binary16 rounding scale.  Probe shape (6 prompts,
    // max_new 8, seed 2) is shared with bench_snapshot's gate.
    let cfg = ServingConfig::default();
    for kind in
        [EngineKind::Baseline, EngineKind::FtFull, EngineKind::FtPruned]
    {
        let rep = precision::compare(&cfg, kind, 6, 8, 2)
            .unwrap_or_else(|e| panic!("{kind:?}: {e}"));
        assert!(rep.compared_tokens > 0, "{kind:?}: nothing compared");
        assert_eq!(
            rep.match_rate, 1.0,
            "{kind:?}: fp16 flipped {} of {} greedy tokens",
            rep.compared_tokens - rep.matched_tokens,
            rep.compared_tokens
        );
        assert!(
            rep.max_abs_logit_div > 0.0,
            "{kind:?}: fp16 ran bitwise-identical to fp32 — \
             quantization cannot be active"
        );
        assert!(
            rep.max_abs_logit_div < 0.05,
            "{kind:?}: logit divergence {} over budget",
            rep.max_abs_logit_div
        );
    }
}

#[test]
fn fp16_server_streams_match_fp32_server() {
    // End-to-end across the serving stack: the same texts through an
    // fp32 and an fp16 embedded server produce identical greedy
    // streams on the synthetic model, and fp16 replies say so.
    let max_new = 8;
    let texts: Vec<String> = precision::probe_inputs(6, max_new, 2)
        .iter()
        .map(|input| {
            input.prompt[1..input.prompt.len() - 1]
                .iter()
                .map(|&id| {
                    aigc_infer::tokenizer::vocab::render_rank(
                        (id - special::FIRST_WORD) as usize,
                    )
                })
                .collect::<Vec<_>>()
                .join(" ")
        })
        .collect();
    let run = |dtype: DType| -> Vec<(u64, Vec<u32>, Option<&'static str>)> {
        let server = Server::builder()
            .engine(EngineKind::FtPruned)
            .dtype(dtype)
            .max_new_tokens(max_new)
            .start()
            .unwrap();
        let streams: Vec<_> = texts
            .iter()
            .map(|t| server.submit(t.clone(), max_new).unwrap())
            .collect();
        let mut out: Vec<(u64, Vec<u32>, Option<&'static str>)> = streams
            .into_iter()
            .map(|s| {
                let resp = s.wait().expect("terminal");
                assert!(resp.error.is_none(), "{:?}", resp.error);
                (resp.id, resp.summary_ids, resp.dtype)
            })
            .collect();
        out.sort();
        out
    };
    let fp32 = run(DType::F32);
    let fp16 = run(DType::F16);
    assert!(fp32.iter().all(|(_, _, d)| *d == Some("fp32")));
    assert!(fp16.iter().all(|(_, _, d)| *d == Some("fp16")));
    let ids32: Vec<&Vec<u32>> = fp32.iter().map(|(_, s, _)| s).collect();
    let ids16: Vec<&Vec<u32>> = fp16.iter().map(|(_, s, _)| s).collect();
    assert_eq!(ids32, ids16, "fp16 serving diverged from fp32");
    assert!(
        ids32.iter().map(|s| s.len()).sum::<usize>() > 0,
        "comparison was vacuous"
    );
}

#[test]
fn server_v2_fp16_done_line_reports_dtype() {
    let addr = "127.0.0.1:17177";
    let shutdown = Arc::new(AtomicBool::new(false));
    let sd = shutdown.clone();
    let mut scfg = cfg(EngineKind::FtPruned, true);
    scfg.dtype = DType::F16;
    scfg.batch.max_wait_ms = 5;
    let server = std::thread::spawn(move || {
        let _ = aigc_infer::server::serve(scfg, addr, sd);
    });
    let deadline = Instant::now() + std::time::Duration::from_secs(30);
    let stream = loop {
        match std::net::TcpStream::connect(addr) {
            Ok(s) => break s,
            Err(e) if Instant::now() >= deadline => {
                panic!("server did not come up: {e}")
            }
            Err(_) => {
                std::thread::sleep(std::time::Duration::from_millis(50))
            }
        }
    };
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut writer = stream;
    writeln!(
        writer,
        "{{\"v\": 2, \"id\": 5, \"text\": \"ba gedu fi\", \
         \"max_new_tokens\": 6}}"
    )
    .unwrap();
    let terminal = loop {
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let v = aigc_infer::util::json::parse(&line).unwrap();
        match v.get("event").as_str() {
            Some("token") => continue,
            Some("done") | Some("error") => break v,
            other => panic!("unexpected event {other:?}: {line}"),
        }
    };
    assert_eq!(terminal.get("event").as_str(), Some("done"));
    assert_eq!(
        terminal.get("dtype").as_str(),
        Some("fp16"),
        "v2 done line must report the serving precision"
    );
    shutdown.store(true, Ordering::Relaxed);
    drop(writer);
    drop(reader);
    let _ = server.join();
}

// --------------------------------------------- poisoned-session contract

/// A backend that injects a failure on the Nth execute — drives the
/// decode session into the poisoned state (KV handles consumed, no
/// replacement) that used to panic the worker thread.
struct FailingBackend {
    inner: RefBackend,
    calls: std::sync::atomic::AtomicUsize,
    fail_on: usize,
}

impl Backend for FailingBackend {
    fn name(&self) -> &'static str {
        "failing"
    }

    fn manifest(&self) -> &aigc_infer::runtime::Manifest {
        self.inner.manifest()
    }

    fn stats(&self) -> aigc_infer::runtime::RuntimeStats {
        self.inner.stats()
    }

    fn prepare(&self, name: &str) -> aigc_infer::Result<()> {
        self.inner.prepare(name)
    }

    fn execute(
        &self,
        name: &str,
        data: Vec<DataArg>,
    ) -> aigc_infer::Result<Vec<ExecOut>> {
        let call = self.calls.fetch_add(1, Ordering::Relaxed) + 1;
        if call == self.fail_on {
            return Err(aigc_infer::Error::Other(
                "injected backend failure".into(),
            ));
        }
        self.inner.execute(name, data)
    }

    fn host_weights(
        &self,
        key: &str,
    ) -> Option<&aigc_infer::runtime::HostWeights> {
        self.inner.host_weights(key)
    }
}

#[test]
fn poisoned_ft_session_returns_typed_errors_not_panics() {
    let backend: Arc<dyn Backend> = Arc::new(FailingBackend {
        inner: RefBackend::synthetic(),
        calls: std::sync::atomic::AtomicUsize::new(0),
        fail_on: 2, // call 1 = prefill (ok), call 2 = first decode
    });
    let engine = aigc_infer::engine::FtEngine::new(
        backend,
        "full",
        false, // single-step decode: the failing call is deterministic
    )
    .unwrap();
    let inputs = seeded_prompts(2, 5, 6, None);
    let mut sampler = Sampler::greedy();
    let mut session = engine.start(&inputs).unwrap();
    // step 1 samples the parked prefill logits (no graph call)
    session.step(&mut sampler).expect("pending-logits step");
    // step 2 hits the injected decode failure: typed error, session dead
    let err = session.step(&mut sampler).unwrap_err();
    assert_eq!(err.code(), "engine_error");
    assert!(err.to_string().contains("injected"), "{err}");
    // the poisoned session keeps failing REQUESTS with a typed error —
    // this used to be `expect("session has no k cache")`, a panic that
    // took the whole inference worker thread down
    let err = session.step(&mut sampler).unwrap_err();
    assert_eq!(err.code(), "engine_error");
    assert!(
        err.to_string().contains("poisoned"),
        "expected the poisoned-session error, got: {err}"
    );
}

/// A backend that silently drops all but the first output of the Nth
/// execute — the "too few outputs" contract breach that used to panic
/// the worker thread in `outs.next().unwrap()`.
struct TruncatingBackend {
    inner: RefBackend,
    calls: std::sync::atomic::AtomicUsize,
    truncate_on: usize,
}

impl Backend for TruncatingBackend {
    fn name(&self) -> &'static str {
        "truncating"
    }

    fn manifest(&self) -> &aigc_infer::runtime::Manifest {
        self.inner.manifest()
    }

    fn stats(&self) -> aigc_infer::runtime::RuntimeStats {
        self.inner.stats()
    }

    fn prepare(&self, name: &str) -> aigc_infer::Result<()> {
        self.inner.prepare(name)
    }

    fn execute(
        &self,
        name: &str,
        data: Vec<DataArg>,
    ) -> aigc_infer::Result<Vec<ExecOut>> {
        let outs = self.inner.execute(name, data)?;
        let call = self.calls.fetch_add(1, Ordering::Relaxed) + 1;
        if call == self.truncate_on {
            Ok(outs.into_iter().take(1).collect())
        } else {
            Ok(outs)
        }
    }

    fn host_weights(
        &self,
        key: &str,
    ) -> Option<&aigc_infer::runtime::HostWeights> {
        self.inner.host_weights(key)
    }
}

#[test]
fn missing_backend_outputs_fail_typed_not_panic() {
    // Satellite: the FT engine's output unpacking must turn a backend
    // that breaks its contract into typed `engine_error` failures for
    // the REQUESTS, never a worker-thread panic.  (This wrapper has no
    // paged support, so the engine exercises the contiguous path whose
    // unpacking used to be `outs.next().unwrap()`.)
    let inputs = seeded_prompts(2, 5, 6, None);

    // case 1: the PREFILL call comes back truncated -> start() fails
    let backend: Arc<dyn Backend> = Arc::new(TruncatingBackend {
        inner: RefBackend::synthetic(),
        calls: std::sync::atomic::AtomicUsize::new(0),
        truncate_on: 1,
    });
    let engine =
        aigc_infer::engine::FtEngine::new(backend, "full", false).unwrap();
    let err = engine.start(&inputs).unwrap_err();
    assert_eq!(err.code(), "engine_error");
    assert!(err.to_string().contains("too few outputs"), "{err}");

    // case 2: the first DECODE call comes back truncated -> that step
    // fails typed, and the session is poisoned (typed) afterwards
    let backend: Arc<dyn Backend> = Arc::new(TruncatingBackend {
        inner: RefBackend::synthetic(),
        calls: std::sync::atomic::AtomicUsize::new(0),
        truncate_on: 2, // call 1 = prefill (intact), call 2 = decode
    });
    let engine =
        aigc_infer::engine::FtEngine::new(backend, "full", false).unwrap();
    let mut sampler = Sampler::greedy();
    let mut session = engine.start(&inputs).unwrap();
    session.step(&mut sampler).expect("pending-logits step");
    let err = session.step(&mut sampler).unwrap_err();
    assert_eq!(err.code(), "engine_error");
    assert!(err.to_string().contains("too few outputs"), "{err}");
    let err = session.step(&mut sampler).unwrap_err();
    assert_eq!(err.code(), "engine_error");
    assert!(err.to_string().contains("poisoned"), "{err}");
}

#[test]
fn pruned_server_rejects_oov_and_resegments_by_default() {
    use aigc_infer::config::{OovPolicy, PruneConfig};
    use aigc_infer::pruning::TokenRemap;
    use aigc_infer::tokenizer::vocab::render_rank;

    // Mirror the server-side derivation (deterministic in seed,
    // coverage and full vocab) to find a word the kept set drops but
    // the ft_pruned engine's ORIGINAL 4000-id vocab still encodes as a
    // single token.
    let prune = PruneConfig { coverage: 0.9, ..PruneConfig::default() };
    let full_vocab = RefBackend::synthetic()
        .manifest()
        .config_for("full")
        .vocab_size;
    let orig_vocab = RefBackend::synthetic()
        .manifest()
        .config_for("pruned")
        .vocab_size as u32;
    let remap = TokenRemap::derive(&prune, full_vocab);
    let dropped = (special::FIRST_WORD..orig_vocab)
        .rev()
        .find(|&t| remap.to_dense(t).is_none())
        .expect("coverage 0.9 must drop ids below the engine vocab");
    let rare = render_rank((dropped - special::FIRST_WORD) as usize);
    let text = format!("ba gedu {rare}");

    // reject policy: the OOV id becomes a typed bad_request terminal
    // event naming the offender, and the pipeline keeps serving
    let server = Server::builder()
        .engine(EngineKind::FtPruned)
        .prune(0.9)
        .prune_oov(OovPolicy::Reject)
        .max_new_tokens(8)
        .start()
        .unwrap();
    let resp = server.submit(text.clone(), 8).unwrap().wait().unwrap();
    assert_eq!(resp.code, Some("bad_request"), "{resp:?}");
    let msg = resp.error.expect("oov rejection carries a message");
    assert!(msg.contains(&dropped.to_string()), "{msg}");
    assert_eq!(resp.pruned_vocab, None, "failed replies omit the pair");
    let ok = server.submit("ba gedu fi", 8).unwrap().wait().unwrap();
    assert!(ok.error.is_none(), "{:?}", ok.error);
    assert_eq!(
        ok.pruned_vocab,
        Some((remap.dense_vocab() as u64, full_vocab as u64)),
        "successful replies report kept/full vocab"
    );
    drop(server);

    // default policy (resegment): the SAME text succeeds — the
    // tokenizer splits the rare word into kept pieces — and every
    // generated id maps back inside the kept set
    let server = Server::builder()
        .engine(EngineKind::FtPruned)
        .prune(0.9)
        .max_new_tokens(8)
        .start()
        .unwrap();
    let resp = server.submit(text, 8).unwrap().wait().unwrap();
    assert!(resp.error.is_none(), "{:?}", resp.error);
    for &t in &resp.summary_ids {
        assert!(
            remap.to_dense(t).is_some(),
            "generated id {t} escaped the kept set"
        );
    }
}

/// Real-artifact tests.  The `pjrt` feature only compiles after the
/// vendored `xla` crate is added as a dependency (see the note in
/// rust/Cargo.toml); on such a build these stay `#[ignore]`d until
/// `make artifacts` output exists — run with `-- --ignored` on a
/// prepared machine.
#[cfg(feature = "pjrt")]
mod pjrt_real {
    use super::*;
    use aigc_infer::config::BackendKind;

    #[test]
    #[ignore = "requires artifacts/ from `make artifacts`"]
    fn real_artifacts_serve_and_match_reference_contract() {
        let mut c = cfg(EngineKind::FtPruned, false);
        c.backend = BackendKind::Pjrt;
        let reqs = workload(4, 5);
        let s = pipeline::run(&c, &reqs).expect("pjrt run");
        assert_eq!(s.responses.len(), reqs.len());
    }
}
