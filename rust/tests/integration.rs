//! Integration tests over the REAL artifacts (`make artifacts` first).
//!
//! These exercise the full L3→PJRT→L2/L1 stack: manifest load, weight
//! upload, graph execution, engine equivalence across the Table 1 ladder,
//! pipeline modes, and the TCP server.

use std::io::{BufRead, BufReader, Write};
use std::rc::Rc;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Instant;

use aigc_infer::config::{EngineKind, ServingConfig};
use aigc_infer::coordinator::request::summary_accuracy;
use aigc_infer::data::{CorpusConfig, Generator, TraceConfig, TraceGenerator};
use aigc_infer::engine::{build as build_engine, EngineInput, Sampler};
use aigc_infer::pipeline;
use aigc_infer::runtime::{DataArg, Runtime};
use aigc_infer::special;

const ARTIFACTS: &str = "artifacts";

fn runtime() -> Rc<Runtime> {
    Rc::new(
        Runtime::new(ARTIFACTS)
            .expect("artifacts/ missing — run `make artifacts` first"),
    )
}

fn cfg(engine: EngineKind, pipelined: bool) -> ServingConfig {
    let mut c = ServingConfig::default();
    c.artifacts_dir = ARTIFACTS.into();
    c.engine = engine;
    c.pipelined = pipelined;
    c.gen.max_new_tokens = 8;
    c
}

fn workload(n: usize, seed: u64) -> Vec<aigc_infer::data::Request> {
    let mut t = TraceGenerator::new(
        TraceConfig { max_new_tokens: 8, ..Default::default() },
        seed,
    );
    t.take(n)
}

fn inputs_from_docs(n: usize, seed: u64, max_new: usize) -> Vec<EngineInput> {
    let mut gen = Generator::new(CorpusConfig::default(), seed);
    (0..n)
        .map(|i| {
            let d = gen.generate_capped(20);
            let mut prompt = vec![special::BOS];
            prompt.extend_from_slice(&d.doc_tokens);
            prompt.push(special::SEP);
            EngineInput {
                request_id: i as u64,
                prompt,
                max_new_tokens: max_new,
            }
        })
        .collect()
}

#[test]
fn manifest_loads_and_inventory_is_complete() {
    let rt = runtime();
    let m = &rt.manifest;
    assert_eq!(m.version, 1);
    for kind in ["baseline_fwd", "ft_prefill", "ft_decode", "ft_decode_multi"]
    {
        assert!(
            m.artifacts.iter().any(|a| a.kind == kind),
            "missing kind {kind}"
        );
    }
    // pruned config is actually pruned
    let full = m.config_for("full");
    let pruned = m.config_for("pruned");
    assert!(pruned.vocab_size < full.vocab_size);
    assert!(pruned.max_position < full.max_position);
}

#[test]
fn raw_graph_execution_shapes() {
    let rt = runtime();
    let entry = rt.select("ft_prefill", "full", 1, 32).unwrap();
    assert_eq!((entry.batch, entry.seq), (1, 32));
    let name = entry.name.clone();
    let exe = rt.load(&name).unwrap();
    let tokens: Vec<i32> = {
        let mut t = vec![special::PAD as i32; 32];
        t[0] = special::BOS as i32;
        for (i, slot) in t.iter_mut().enumerate().take(9).skip(1) {
            *slot = (special::FIRST_WORD + i as u32) as i32;
        }
        t[9] = special::SEP as i32;
        t
    };
    let outs = rt
        .run(
            &exe,
            vec![
                DataArg::I32(tokens, vec![1, 32]),
                DataArg::I32(vec![10], vec![1]),
            ],
        )
        .unwrap();
    assert_eq!(outs.len(), 3); // logits + k_cache + v_cache
    let logits = outs[0].to_vec::<f32>().unwrap();
    assert_eq!(logits.len(), rt.manifest.config_for("full").vocab_size);
    assert!(logits.iter().all(|v| v.is_finite()));
}

#[test]
fn bucket_selection_prefers_cheapest() {
    let rt = runtime();
    let e = rt.select("ft_prefill", "full", 2, 40).unwrap();
    assert_eq!((e.batch, e.seq), (4, 64));
    let e = rt.select("baseline_fwd", "baseline", 1, 1).unwrap();
    assert_eq!((e.batch, e.seq), (1, 32));
    assert!(rt.select("ft_prefill", "full", 9, 32).is_err());
    assert!(rt.select("ft_prefill", "pruned", 1, 512).is_err());
}

#[test]
fn ft_matches_baseline_greedy_tokens() {
    // The FT engine (fp16 + KV cache + fused kernels) must generate
    // essentially the same greedy continuations as the naive fp32
    // baseline: the optimizations change speed, not answers (§4).
    let rt = runtime();
    let baseline = build_engine(
        EngineKind::Baseline,
        rt.clone(),
        Default::default(),
    )
    .unwrap();
    let ft =
        build_engine(EngineKind::FtFull, rt.clone(), Default::default())
            .unwrap();
    let inputs = inputs_from_docs(4, 11, 8);
    let a = baseline.generate(&inputs, &mut Sampler::greedy()).unwrap();
    let b = ft.generate(&inputs, &mut Sampler::greedy()).unwrap();
    let mut matches = 0usize;
    let mut total = 0usize;
    for (x, y) in a.iter().zip(&b) {
        total += x.generated.len().max(y.generated.len());
        matches += x
            .generated
            .iter()
            .zip(&y.generated)
            .filter(|(p, q)| p == q)
            .count();
    }
    assert!(total > 0);
    let agree = matches as f64 / total as f64;
    assert!(agree >= 0.75, "fp16/fp32 greedy agreement only {agree}");
}

#[test]
fn multi_step_equals_single_step() {
    // Same graphs, same dtype, both greedy: bitwise-identical tokens.
    let rt = runtime();
    let multi = build_engine(
        EngineKind::FtPruned,
        rt.clone(),
        aigc_infer::config::GenConfig { max_new_tokens: 12, use_multi_step: true },
    )
    .unwrap();
    let single = build_engine(
        EngineKind::FtPruned,
        rt.clone(),
        aigc_infer::config::GenConfig {
            max_new_tokens: 12,
            use_multi_step: false,
        },
    )
    .unwrap();
    let inputs = inputs_from_docs(3, 22, 12);
    let a = multi.generate(&inputs, &mut Sampler::greedy()).unwrap();
    let b = single.generate(&inputs, &mut Sampler::greedy()).unwrap();
    for (x, y) in a.iter().zip(&b) {
        assert_eq!(x.generated, y.generated);
    }
}

#[test]
fn pruned_engine_still_summarizes() {
    let rt = runtime();
    let ft = build_engine(EngineKind::FtPruned, rt, Default::default())
        .unwrap();
    let mut gen = Generator::new(CorpusConfig::default(), 33);
    let docs: Vec<_> = (0..4).map(|_| gen.generate_capped(20)).collect();
    let inputs: Vec<EngineInput> = docs
        .iter()
        .enumerate()
        .map(|(i, d)| {
            let mut prompt = vec![special::BOS];
            prompt.extend_from_slice(&d.doc_tokens);
            prompt.push(special::SEP);
            EngineInput { request_id: i as u64, prompt, max_new_tokens: 8 }
        })
        .collect();
    let outs = ft.generate(&inputs, &mut Sampler::greedy()).unwrap();
    // trained model should beat chance comfortably on the copy task
    let acc: f64 = docs
        .iter()
        .zip(&outs)
        .map(|(d, o)| summary_accuracy(&o.generated, &d.summary_tokens))
        .sum::<f64>()
        / docs.len() as f64;
    assert!(acc > 0.05, "summary accuracy {acc} — model collapsed?");
}

#[test]
fn top_k_sampling_generates_valid_ids() {
    let rt = runtime();
    let vocab = rt.manifest.config_for("pruned").vocab_size as u32;
    let ft = build_engine(EngineKind::FtPruned, rt, Default::default())
        .unwrap();
    let inputs = inputs_from_docs(2, 44, 6);
    let outs = ft
        .generate(&inputs, &mut Sampler::top_k(8, 0.9, 123))
        .unwrap();
    for o in outs {
        for &t in &o.generated {
            assert!(t < vocab);
            assert_ne!(t, special::EOS);
        }
    }
}

#[test]
fn pipelined_equals_sequential_results() {
    let reqs = workload(12, 55);
    let seq = pipeline::run(&cfg(EngineKind::FtPruned, false), &reqs)
        .unwrap();
    let par = pipeline::run(&cfg(EngineKind::FtPruned, true), &reqs)
        .unwrap();
    assert_eq!(seq.responses.len(), reqs.len());
    assert_eq!(par.responses.len(), reqs.len());
    let mut a: Vec<_> = seq
        .responses
        .iter()
        .map(|r| (r.id, r.summary_ids.clone()))
        .collect();
    let mut b: Vec<_> = par
        .responses
        .iter()
        .map(|r| (r.id, r.summary_ids.clone()))
        .collect();
    a.sort();
    b.sort();
    // Greedy decoding is deterministic; batch composition can differ
    // between executors (timing-dependent flushes), which changes padding
    // and can occasionally change a bucket choice — identity must hold on
    // ids and overwhelmingly on tokens.
    assert_eq!(
        a.iter().map(|(i, _)| *i).collect::<Vec<_>>(),
        b.iter().map(|(i, _)| *i).collect::<Vec<_>>()
    );
    let same = a
        .iter()
        .zip(&b)
        .filter(|((_, x), (_, y))| x == y)
        .count();
    assert!(
        same * 10 >= a.len() * 8,
        "only {same}/{} identical summaries",
        a.len()
    );
}

#[test]
fn server_round_trip() {
    let addr = "127.0.0.1:17071";
    let shutdown = Arc::new(AtomicBool::new(false));
    let sd = shutdown.clone();
    let mut scfg = cfg(EngineKind::FtPruned, true);
    scfg.batch.max_wait_ms = 5;
    let server = std::thread::spawn(move || {
        let _ = aigc_infer::server::serve(scfg, addr, sd);
    });
    // wait for the listener
    let mut stream = None;
    let deadline = Instant::now() + std::time::Duration::from_secs(30);
    while Instant::now() < deadline {
        match std::net::TcpStream::connect(addr) {
            Ok(s) => {
                stream = Some(s);
                break;
            }
            Err(_) => std::thread::sleep(std::time::Duration::from_millis(50)),
        }
    }
    let stream = stream.expect("server did not come up");
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut writer = stream;

    let mut gen = Generator::new(CorpusConfig::default(), 66);
    for i in 0..3 {
        let d = gen.generate_capped(16);
        writeln!(
            writer,
            "{{\"id\": {i}, \"text\": \"{}\", \"max_new_tokens\": 4}}",
            d.text
        )
        .unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let v = aigc_infer::util::json::parse(&line).unwrap();
        assert_eq!(v.get("id").as_u64(), Some(i));
        assert!(v.get("summary").as_str().is_some());
        assert!(v.get("latency_ms").as_f64().unwrap() > 0.0);
    }
    // malformed line gets an error object, not a hang
    writeln!(writer, "{{\"nope\": 1}}").unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("error"));

    shutdown.store(true, Ordering::Relaxed);
    drop(writer);
    drop(reader);
    let _ = server.join();
}
