//! BENCH A3 — ablation of dynamic batch size (§2.3): serving throughput
//! and latency as the batch cap grows (1 → 4 → 8).
//!
//! Env: BENCH_N (default 32).

use aigc_infer::config::{EngineKind, ServingConfig};
use aigc_infer::data::{TraceConfig, TraceGenerator};
use aigc_infer::pipeline;

fn main() {
    let n: usize = std::env::var("BENCH_N")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(32);
    println!("# A3: throughput vs dynamic batch cap ({n} requests, ft_pruned)\n");
    println!(
        "{:>10} {:>14} {:>14} {:>14}",
        "max_batch", "samples/s", "mean lat", "p95 lat"
    );
    let mut prev = None;
    for max_batch in [1usize, 4, 8] {
        let mut cfg = ServingConfig::default();
        cfg.engine = EngineKind::FtPruned;
        cfg.pipelined = false;
        cfg.gen.max_new_tokens = 12;
        cfg.batch.max_batch = max_batch;
        cfg.precompile = true;
        let mut trace = TraceGenerator::new(
            TraceConfig { max_new_tokens: 12, ..Default::default() },
            2,
        );
        let reqs = trace.take(n);
        let s = pipeline::run(&cfg, &reqs).expect("run");
        println!(
            "{:>10} {:>14.2} {:>12.1}ms {:>12.1}ms",
            max_batch,
            s.samples_per_sec,
            s.latency.mean().as_secs_f64() * 1e3,
            s.latency.quantile(0.95).as_secs_f64() * 1e3,
        );
        if let Some(p) = prev {
            let _: f64 = p; // previous speed retained for shape inspection
        }
        prev = Some(s.samples_per_sec);
    }
    println!(
        "\nshape check: throughput rises with batch (GPU-style utilization\n\
         gain, bounded on 1 CPU core); per-request latency rises modestly."
    );
}
