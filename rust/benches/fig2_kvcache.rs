//! BENCH F2 — the KV-cache mechanism of paper Fig 2, measured.
//!
//! Fig 2 is a schematic (cache K/V once, reuse every step).  The
//! measurable claim behind it: WITHOUT a cache each emitted token costs a
//! full-sequence forward (cost grows with context length S); WITH the
//! cache a decode step does O(S) attention reads but O(1) projections —
//! per-token cost is flat and far smaller.
//!
//! We time, per sequence-length bucket: one baseline full forward (=
//! baseline per-token cost) vs one fused decode step (= FT per-token
//! cost), plus the fused multi-step variant (per-token amortized).
//! Runs on the default-config backend — always the hermetic reference
//! backend (interpreting `artifacts/` weights when that directory
//! exists); PJRT timings would need a config with `backend: pjrt` and
//! a `--features pjrt` build.

use aigc_infer::config::ServingConfig;
use aigc_infer::runtime::{backend_for, Backend, DataArg};
use aigc_infer::special;
use aigc_infer::util::bench;

fn tokens(b: usize, s: usize, len: usize) -> Vec<i32> {
    let mut t = vec![special::PAD as i32; b * s];
    for row in 0..b {
        t[row * s] = special::BOS as i32;
        for j in 1..len {
            t[row * s + j] = (special::FIRST_WORD + j as u32) as i32;
        }
    }
    t
}

fn main() {
    let backend = backend_for(&ServingConfig::default()).expect("backend");
    let b = 4usize;
    let iters = 10;
    println!(
        "# Fig 2 (measured, {} backend): per-token cost, recompute vs KV cache\n",
        backend.name()
    );
    println!(
        "{:>6} {:>22} {:>22} {:>22} {:>9}",
        "seq", "baseline fwd/token", "ft decode/token", "ft multi/token", "speedup"
    );

    let seq_lens = backend.manifest().seq_lens.clone();
    for &s in &seq_lens {
        let len = s / 2;
        // baseline: one full forward == cost of ONE token
        let base_name = backend
            .manifest()
            .select("baseline_fwd", "baseline", b, s)
            .unwrap()
            .name
            .clone();
        let toks = tokens(b, s, len);
        let lens = vec![len as i32; b];
        let sample_base = bench::time(&format!("baseline_s{s}"), 2, iters, || {
            backend
                .execute(
                    &base_name,
                    vec![
                        DataArg::I32(toks.clone(), vec![b, s]),
                        DataArg::I32(lens.clone(), vec![b]),
                    ],
                )
                .unwrap();
        });

        // ft: prefill once to get caches, then time single decode steps
        let pre_name = backend
            .manifest()
            .select("ft_prefill", "full", b, s)
            .unwrap()
            .name
            .clone();
        let outs = backend
            .execute(
                &pre_name,
                vec![
                    DataArg::I32(toks.clone(), vec![b, s]),
                    DataArg::I32(lens.clone(), vec![b]),
                ],
            )
            .unwrap();
        let mut it = outs.into_iter();
        let _logits = it.next().unwrap();
        let k0 = it.next().unwrap().into_opaque().unwrap();
        let v0 = it.next().unwrap().into_opaque().unwrap();

        let find = |kind: &str| {
            backend
                .manifest()
                .find_exact(kind, "full", b, s)
                .map(|a| (a.name.clone(), a.steps))
                .unwrap()
        };
        let (dec_name, _) = find("ft_decode");
        let tok1 = vec![special::FIRST_WORD as i32; b];
        let pos1 = vec![len as i32; b];
        // each iteration re-feeds the same caches (cost-identical)
        let sample_dec = bench::time(&format!("decode_s{s}"), 2, iters, || {
            backend
                .execute(
                    &dec_name,
                    vec![
                        DataArg::I32(tok1.clone(), vec![b]),
                        DataArg::I32(pos1.clone(), vec![b]),
                        DataArg::Opaque(k0.clone()),
                        DataArg::Opaque(v0.clone()),
                    ],
                )
                .unwrap();
        });

        let (multi_name, multi_steps) = find("ft_decode_multi");
        let steps = multi_steps.unwrap_or(8);
        let sample_multi =
            bench::time(&format!("multi_s{s}"), 2, iters, || {
                backend
                    .execute(
                        &multi_name,
                        vec![
                            DataArg::I32(tok1.clone(), vec![b]),
                            DataArg::I32(pos1.clone(), vec![b]),
                            DataArg::Opaque(k0.clone()),
                            DataArg::Opaque(v0.clone()),
                        ],
                    )
                    .unwrap();
            });

        let per_tok_multi = sample_multi.mean / steps as u32;
        println!(
            "{:>6} {:>22} {:>22} {:>22} {:>8.1}x",
            s,
            bench::fmt_dur(sample_base.mean),
            bench::fmt_dur(sample_dec.mean),
            bench::fmt_dur(per_tok_multi),
            sample_base.mean.as_secs_f64()
                / per_tok_multi.as_secs_f64().max(1e-12),
        );
    }
    println!(
        "\nshape check: baseline/token grows with seq; decode/token ~flat;\n\
         the gap IS the KV cache (paper Fig 2).  multi additionally\n\
         amortizes the engine<->backend cache round-trip (§Perf)."
    );
}
