//! BENCH A1 — ablation of §3.2 embedding-layer pruning: coverage-vs-size
//! trade-off, serving throughput, and the quality guard.
//!
//! Rows: ft_full (8000 vocab / 512 pos) vs ft_pruned (4000 / 128) on the
//! same workload, plus the analytic/empirical coverage curve the trim is
//! based on.  Env: BENCH_N (default 32).

use aigc_infer::config::{EngineKind, ServingConfig};
use aigc_infer::data::{CorpusConfig, TraceConfig, TraceGenerator};
use aigc_infer::pipeline;
use aigc_infer::pruning::PruningAnalysis;

fn main() {
    let n: usize = std::env::var("BENCH_N")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(32);

    println!("# A1: embedding-pruning ablation\n");
    println!("## coverage curve (what a frequency-prefix of the vocab retains)");
    let cfg = CorpusConfig::default();
    let a = PruningAnalysis::run(&cfg, 1000, 0);
    for p in a.coverage_curve(cfg.vocab_size) {
        println!(
            "  prefix {:>5} ids -> {:>6.2}% of tokens",
            p.vocab_prefix,
            p.coverage * 100.0
        );
    }

    println!("\n## serving impact (same workload, {n} requests)");
    let mut rows = Vec::new();
    for (label, engine) in [
        ("ft_full   (vocab 8000, pos 512)", EngineKind::FtFull),
        ("ft_pruned (vocab 4000, pos 128)", EngineKind::FtPruned),
    ] {
        let mut scfg = ServingConfig::default();
        scfg.engine = engine;
        scfg.pipelined = false;
        scfg.gen.max_new_tokens = 12;
        scfg.precompile = true;
        let mut trace = TraceGenerator::new(
            TraceConfig { max_new_tokens: 12, ..Default::default() },
            0,
        );
        let reqs = trace.take(n);
        let s = pipeline::run(&scfg, &reqs).expect("run");
        println!(
            "  {label}: {:>7.2} samples/s  acc {:.3}  mean lat {:.1}ms",
            s.samples_per_sec,
            s.mean_accuracy,
            s.latency.mean().as_secs_f64() * 1e3
        );
        rows.push(s);
    }
    println!(
        "\npruning speedup: {:.2}x (paper row 2->3: 125.32/98.46 = 1.27x);\n\
         quality delta: {:+.3} (paper: \"maintaining high levels of performance\")",
        rows[1].samples_per_sec / rows[0].samples_per_sec.max(1e-9),
        rows[1].mean_accuracy - rows[0].mean_accuracy
    );
}
