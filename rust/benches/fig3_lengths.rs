//! BENCH F3 — regenerates paper Fig 3: the sequence-length distribution
//! of the workload, which justifies trimming the position embedding
//! 512→128 (§3.2).
//!
//! Prints the histogram series (bin edge, count) exactly as a plot would
//! consume it, plus the fit fractions at candidate position-table sizes.

use aigc_infer::data::CorpusConfig;
use aigc_infer::pruning::{fit_fraction, length_histogram};

fn main() {
    let cfg = CorpusConfig::default();
    let n = 10_000;
    println!("# Fig 3 (regenerated): document length histogram, {n} docs\n");
    println!("{:>10} {:>8} {:>8}", "len_bin", "count", "cum%");
    let hist = length_histogram(&cfg, n, 0, 20);
    let total: u64 = hist.iter().map(|(_, c)| c).sum();
    let mut cum = 0u64;
    for (edge, count) in &hist {
        cum += count;
        if *count == 0 && cum == total {
            break;
        }
        println!(
            "{:>7}-{:<3} {:>8} {:>7.2}%",
            edge,
            edge + 19,
            count,
            cum as f64 / total as f64 * 100.0
        );
    }
    println!("\n# position-table sizing (paper: 512 -> 128)");
    for maxp in [64usize, 100, 128, 256, 512] {
        println!(
            "  packed sequences fitting {maxp:>3} positions: {:>6.2}%",
            fit_fraction(&cfg, n, 1, maxp) * 100.0
        );
    }
    println!(
        "\nshape check: bulk of mass below 100 tokens (paper: \"input\n\
         sentences typically less than 100 words\"), thin tail to 400."
    );
}
