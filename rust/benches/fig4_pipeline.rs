//! BENCH F4 — paper Fig 4, measured: the four-process parallel pipeline
//! vs. strictly sequential stage execution, same stages, same workload.
//!
//! Reports wall time, per-stage busy time, the Amdahl bound
//! (overlappable fraction) and the realized overlap gain.
//! Env: BENCH_N (default 48).

use aigc_infer::config::{EngineKind, ServingConfig};
use aigc_infer::data::{TraceConfig, TraceGenerator};
use aigc_infer::pipeline;

fn main() {
    let n: usize = std::env::var("BENCH_N")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(48);
    let max_new = 12;

    let mut results = Vec::new();
    for (label, pipelined) in
        [("sequential (rows 1-3)", false), ("pipelined (row 4 / Fig 4)", true)]
    {
        let mut cfg = ServingConfig::default();
        cfg.engine = EngineKind::FtPruned;
        cfg.pipelined = pipelined;
        cfg.gen.max_new_tokens = max_new;
        cfg.precompile = true;
        let mut trace = TraceGenerator::new(
            TraceConfig { max_new_tokens: max_new, ..Default::default() },
            3,
        );
        let reqs = trace.take(n);
        let s = pipeline::run(&cfg, &reqs).expect("run");
        println!(
            "{label:<28} wall {:>7.3}s  speed {:>7.2}/s  \
             pre {:>6.3}s inf {:>6.3}s post {:>6.3}s",
            s.wall.as_secs_f64(),
            s.samples_per_sec,
            s.stages.preprocess.as_secs_f64(),
            s.stages.inference.as_secs_f64(),
            s.stages.postprocess.as_secs_f64(),
        );
        results.push((label, s));
    }

    let seq = &results[0].1;
    let par = &results[1].1;
    println!(
        "\noverlappable fraction (pre+post share of busy): {:.2}%",
        seq.stages.overlappable_fraction() * 100.0
    );
    println!(
        "pipeline gain: {:.3}x (paper row 3->4: 144.45/125.32 = 1.15x on a\n\
         multi-core GPU host; single-core boxes realize only I/O + channel\n\
         slack — DESIGN.md §3)",
        par.samples_per_sec / seq.samples_per_sec.max(1e-9)
    );

    // ---- worker-pool sweep: the model stage itself scales -------------
    // row_threads pinned to 1 so the sweep isolates pool scaling from
    // the reference backend's intra-batch row parallelism.
    println!("\n## worker pool sweep (pipelined, row_threads=1)");
    let mut base = 0.0f64;
    for workers in [1usize, 2, 4] {
        let mut cfg = ServingConfig::default();
        cfg.engine = EngineKind::FtPruned;
        cfg.pipelined = true;
        cfg.workers = workers;
        cfg.row_threads = 1;
        cfg.gen.max_new_tokens = max_new;
        cfg.precompile = true;
        let mut trace = TraceGenerator::new(
            TraceConfig { max_new_tokens: max_new, ..Default::default() },
            3,
        );
        let reqs = trace.take(n);
        let s = pipeline::run(&cfg, &reqs).expect("run");
        if workers == 1 {
            base = s.samples_per_sec;
        }
        println!(
            "workers={workers}  wall {:>7.3}s  speed {:>7.2}/s  \
             ({:.2}x vs 1 worker)  inf busy {:>6.3}s  session {}",
            s.wall.as_secs_f64(),
            s.samples_per_sec,
            s.samples_per_sec / base.max(1e-9),
            s.stages.inference.as_secs_f64(),
            s.session_latency.summary(),
        );
    }
}
