//! BENCH A2 — ablation of length-bucketed batching ("optimized the
//! allocation of data inference order", §1): padding waste and serving
//! throughput with bucketing ON vs OFF (global FIFO).
//!
//! Env: BENCH_N (default 48).

use aigc_infer::config::{BatchPolicy, EngineKind, ServingConfig};
use aigc_infer::coordinator::{DynamicBatcher, PreparedRequest};
use aigc_infer::data::{TraceConfig, TraceGenerator};
use aigc_infer::pipeline;
use aigc_infer::tokenizer::{Encode, FastTokenizer, Vocab};

fn main() {
    let n: usize = std::env::var("BENCH_N")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(48);

    // ---- batcher-level padding waste (pure, no PJRT) -------------------
    println!("# A2: length-bucketed batching\n");
    println!("## padding waste at the batcher (2000 requests, no inference)");
    let tok = FastTokenizer::new(Vocab::synthetic(8000));
    let mut trace = TraceGenerator::new(TraceConfig::default(), 0);
    let prepared: Vec<PreparedRequest> = trace
        .take(2000)
        .into_iter()
        .map(|r| {
            let ids = tok.encode(&r.text, 8000);
            PreparedRequest::new(r.id, ids, r.max_new_tokens)
        })
        .collect();

    for (label, bucketing) in [("bucketed", true), ("fifo    ", false)] {
        let policy = BatchPolicy {
            max_batch: 8,
            max_wait_ms: 0,
            length_bucketing: bucketing,
            ..BatchPolicy::default()
        };
        let mut b = DynamicBatcher::new(policy, vec![32, 64, 128]);
        let mut waste = 0.0;
        let mut batches = 0usize;
        for r in prepared.iter().cloned() {
            b.push(r);
            while let Some(batch) = b.pop(false) {
                waste += batch.padding_waste();
                batches += 1;
            }
        }
        while let Some(batch) = b.pop(true) {
            waste += batch.padding_waste();
            batches += 1;
        }
        println!(
            "  {label}: mean padding waste {:>6.2}% over {batches} batches",
            waste / batches as f64 * 100.0
        );
    }

    // ---- end-to-end serving impact -------------------------------------
    println!("\n## serving impact ({n} requests, ft_pruned, sequential)");
    let mut speeds = Vec::new();
    for (label, bucketing) in [("bucketed", true), ("fifo    ", false)] {
        let mut cfg = ServingConfig::default();
        cfg.engine = EngineKind::FtPruned;
        cfg.pipelined = false;
        cfg.gen.max_new_tokens = 12;
        cfg.batch.length_bucketing = bucketing;
        cfg.precompile = true;
        let mut trace = TraceGenerator::new(
            TraceConfig { max_new_tokens: 12, ..Default::default() },
            1,
        );
        let reqs = trace.take(n);
        let s = pipeline::run(&cfg, &reqs).expect("run");
        println!(
            "  {label}: {:>7.2} samples/s  mean lat {:.1}ms",
            s.samples_per_sec,
            s.latency.mean().as_secs_f64() * 1e3
        );
        speeds.push(s.samples_per_sec);
    }
    println!(
        "\nbucketing gain: {:.2}x (short prompts stop paying long-prompt padding)",
        speeds[0] / speeds[1].max(1e-9)
    );
}
