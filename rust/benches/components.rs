//! BENCH C — microbenchmarks of the L3 substrates, including the
//! Faster-Tokenizer comparison (§2.3): trie fast path vs textbook
//! WordPiece, plus batcher / JSON / RNG / histogram hot paths.

use aigc_infer::config::BatchPolicy;
use aigc_infer::coordinator::{DynamicBatcher, PreparedRequest};
use aigc_infer::data::{CorpusConfig, Generator, ZipfSampler};
use aigc_infer::metrics::Histogram;
use aigc_infer::runtime::reference::model::{linear, logits_matvec};
use aigc_infer::runtime::{Kernel, WSlice};
use aigc_infer::tokenizer::{Encode, FastTokenizer, SlowTokenizer, Vocab};
use aigc_infer::util::bench::{self, Sample};
use aigc_infer::util::rng::Rng;

fn main() {
    let mut samples: Vec<Sample> = Vec::new();

    // corpus of text to tokenize
    let mut gen = Generator::new(CorpusConfig::default(), 0);
    let docs: Vec<String> =
        (0..200).map(|_| gen.generate().text).collect();
    let total_tokens: u64 =
        docs.iter().map(|d| d.split(' ').count() as u64).sum();

    let vocab = Vocab::synthetic(8000);
    let slow = SlowTokenizer::new(vocab.clone());
    let fast = FastTokenizer::new(vocab.clone());

    // --- Faster Tokenizer ablation --------------------------------------
    let (s, slow_tps) = bench::time_units("tokenizer: slow wordpiece", 1, 5, || {
        let mut n = 0u64;
        for d in &docs {
            n += slow.encode(d, 8000).len() as u64;
        }
        n
    });
    samples.push(s);
    let (s, fast_tps) = bench::time_units("tokenizer: fast trie (LinMaxMatch)", 1, 5, || {
        let mut n = 0u64;
        for d in &docs {
            n += fast.encode(d, 8000).len() as u64;
        }
        n
    });
    samples.push(s);
    // pruned-vocab re-segmentation path
    let (s, _) = bench::time_units("tokenizer: fast, pruned max_id=4000", 1, 5, || {
        let mut n = 0u64;
        for d in &docs {
            n += fast.encode(d, 4000).len() as u64;
        }
        n
    });
    samples.push(s);

    // --- reference GEMM kernels (scalar vs blocked A/B) ------------------
    // the default synthetic preset's shapes: d_model 32, d_ff 64,
    // vocab 8000 (full) — the logits GEMV dominates per-token cost
    let (d, dff, vocab) = (32usize, 64usize, 8000usize);
    let mut krng = Rng::seed_from_u64(0x6E77);
    let mut nz = |n: usize| -> Vec<f32> {
        (0..n)
            .map(|_| (krng.gen_f64() - 0.5) as f32 * 2.0 + 1e-3)
            .collect()
    };
    let x = nz(d);
    let w = nz(d * dff);
    let wb = nz(dff);
    let emb = nz(vocab * d);
    let mut out = vec![0.0f32; dff];
    let mut logits = vec![0.0f32; vocab];
    for kernel in [Kernel::Scalar, Kernel::Blocked] {
        let label = format!("linear {d}x{dff}: {} kernel", kernel.label());
        samples.push(bench::time(&label, 2, 10, || {
            for _ in 0..64 {
                linear(
                    &x,
                    WSlice::F32(&w),
                    WSlice::F32(&wb),
                    d,
                    dff,
                    &mut out,
                    kernel,
                );
            }
            std::hint::black_box(out[0]);
        }));
        let label =
            format!("logits gemv {vocab}x{d}: {} kernel", kernel.label());
        samples.push(bench::time(&label, 2, 10, || {
            logits_matvec(
                &x,
                WSlice::F32(&emb),
                d,
                vocab,
                &mut logits,
                kernel,
            );
            std::hint::black_box(logits[0]);
        }));
    }

    // --- speculative verification (fused vs sequential dispatches) -------
    // paged_verify scores a k-token draft in ONE dispatch; the A/B arm
    // feeds the identical token chain through k+1 paged_decode calls.
    // The delta is the per-dispatch overhead (scratch fit, table checks,
    // embedding walk setup) that fused verification amortizes.
    {
        use aigc_infer::runtime::{
            Backend, PagedDecodeRow, PagedPrefillRow, RefBackend,
        };
        let b = RefBackend::synthetic();
        let lanes = 4usize;
        let k = 4usize; // draft length
        let block_size = 16usize;
        let mut prompt = vec![aigc_infer::special::BOS as i32];
        for _ in 0..6 {
            prompt.extend_from_slice(&[5, 9]);
        }
        prompt.push(aigc_infer::special::SEP as i32);
        let blocks_per =
            (prompt.len() + k + 1).div_ceil(block_size).max(1);
        let (pk, pv) = b
            .paged_kv_alloc("full", lanes * blocks_per, block_size)
            .unwrap();
        let tables: Vec<Vec<u32>> = (0..lanes)
            .map(|l| {
                ((l * blocks_per) as u32..((l + 1) * blocks_per) as u32)
                    .collect()
            })
            .collect();
        let prefill_rows: Vec<PagedPrefillRow> = tables
            .iter()
            .map(|t| PagedPrefillRow {
                tokens: prompt.clone(),
                start: 0,
                blocks: t.clone(),
            })
            .collect();
        let (logits, pk, pv) =
            b.paged_prefill("full", pk, pv, &prefill_rows).unwrap();
        let vocab = logits.len() / lanes;
        let first: Vec<i32> = (0..lanes)
            .map(|l| {
                logits[l * vocab..(l + 1) * vocab]
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.total_cmp(b.1))
                    .map(|(i, _)| i as i32)
                    .unwrap_or(0)
            })
            .collect();
        let at = prompt.len() as i32;
        let verify_rows: Vec<PagedDecodeRow> = (0..lanes)
            .map(|l| PagedDecodeRow {
                token: first[l],
                position: at,
                blocks: tables[l].clone(),
            })
            .collect();
        let drafts: Vec<Vec<i32>> = vec![vec![5, 9, 13, 7]; lanes];
        let label =
            format!("spec verify: {lanes} lanes, k={k}, 1 fused dispatch");
        samples.push(bench::time(&label, 2, 10, || {
            let (outs, _, _) = b
                .paged_verify(
                    "full",
                    pk.clone(),
                    pv.clone(),
                    &verify_rows,
                    &drafts,
                )
                .unwrap();
            std::hint::black_box(outs[0]);
        }));
        let label = format!(
            "spec verify: {lanes} lanes, k={k}, {} sequential dispatches",
            k + 1
        );
        samples.push(bench::time(&label, 2, 10, || {
            let mut kh = pk.clone();
            let mut vh = pv.clone();
            for step in 0..=k {
                let rows: Vec<PagedDecodeRow> = (0..lanes)
                    .map(|l| PagedDecodeRow {
                        token: if step == 0 {
                            first[l]
                        } else {
                            drafts[l][step - 1]
                        },
                        position: at + step as i32,
                        blocks: tables[l].clone(),
                    })
                    .collect();
                let (l, k2, v2) =
                    b.paged_decode("full", kh, vh, &rows).unwrap();
                kh = k2;
                vh = v2;
                std::hint::black_box(l[0]);
            }
        }));
    }

    // --- batcher ---------------------------------------------------------
    let policy = BatchPolicy {
        max_batch: 8,
        max_wait_ms: 0,
        length_bucketing: true,
        ..BatchPolicy::default()
    };
    samples.push(bench::time("batcher: push+pop 1000 reqs", 1, 10, || {
        let mut b = DynamicBatcher::new(policy.clone(), vec![32, 64, 128]);
        for i in 0..1000u64 {
            b.push(PreparedRequest::new(
                i,
                vec![5; (i % 100) as usize + 1],
                12,
            ));
            while b.pop(false).is_some() {}
        }
        while b.pop(true).is_some() {}
    }));

    // --- zipf / rng -------------------------------------------------------
    let zipf = ZipfSampler::new(8000, 1.1);
    samples.push(bench::time("zipf: 100k samples", 1, 5, || {
        let mut rng = Rng::seed_from_u64(1);
        let mut acc = 0usize;
        for _ in 0..100_000 {
            acc += zipf.sample(&mut rng);
        }
        std::hint::black_box(acc);
    }));

    // --- json (wire protocol + manifest path) ----------------------------
    let manifest_text =
        std::fs::read_to_string("artifacts/manifest.json").ok();
    if let Some(text) = &manifest_text {
        let mb = text.len() as f64 / 1e6;
        let s = bench::time("json: parse manifest.json", 1, 5, || {
            std::hint::black_box(
                aigc_infer::util::json::parse(text).unwrap(),
            );
        });
        eprintln!(
            "  (manifest is {mb:.2} MB -> {:.1} MB/s)",
            mb / s.mean.as_secs_f64()
        );
        samples.push(s);
    }
    let line = r#"{"id": 7, "text": "ba gedu seky mano", "max_new_tokens": 16}"#;
    samples.push(bench::time("json: parse 10k request lines", 1, 5, || {
        for _ in 0..10_000 {
            std::hint::black_box(
                aigc_infer::server::parse_request_line(line).unwrap(),
            );
        }
    }));

    // --- metrics ----------------------------------------------------------
    samples.push(bench::time("histogram: 100k records", 1, 5, || {
        let mut h = Histogram::new();
        for i in 0..100_000u64 {
            h.record(std::time::Duration::from_micros(i % 10_000 + 1));
        }
        std::hint::black_box(h.quantile(0.99));
    }));

    bench::print_table("component microbenchmarks", &samples);
    println!(
        "\nFaster Tokenizer speedup (fast/slow): {:.2}x  \
         ({:.1}M vs {:.1}M tokens/s over {} tokens)",
        fast_tps / slow_tps.max(1e-9),
        fast_tps / 1e6,
        slow_tps / 1e6,
        total_tokens
    );
}
