//! BENCH T1 — regenerates paper Table 1 (the headline ablation ladder).
//!
//! Paper (A100-class GPU, 24L UNIMO, Baidu commercial data):
//!   1 Baseline                           16.11 samples/s
//!   2 + Fast transformer                 98.46  (6.11x)
//!   3 + embedding layer pruning         125.32  (7.78x)
//!   4 + multi-process parallel          144.45  (8.96x)
//!
//! Here: scaled model on CPU PJRT — absolute speeds differ; the target is
//! the ladder SHAPE (each step helps; step 2 dominates; see
//! EXPERIMENTS.md).  Env: BENCH_N (requests, default 32).

use aigc_infer::config::{EngineKind, ServingConfig};
use aigc_infer::data::{TraceConfig, TraceGenerator};
use aigc_infer::metrics::{LadderRow, Report};
use aigc_infer::pipeline;

fn main() {
    let n: usize = std::env::var("BENCH_N")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(32);
    let max_new = 12usize;
    let steps: [(usize, &str, EngineKind, bool); 4] = [
        (1, "Baseline", EngineKind::Baseline, false),
        (2, "Fast transformer", EngineKind::FtFull, false),
        (3, "embedding layer pruning", EngineKind::FtPruned, false),
        (4, "multi-process parallel processing", EngineKind::FtPruned, true),
    ];

    let mut report = Report::default();
    for (step, name, engine, pipelined) in steps {
        let mut cfg = ServingConfig::default();
        cfg.engine = engine;
        cfg.pipelined = pipelined;
        cfg.gen.max_new_tokens = max_new;
        cfg.precompile = true; // startup compile, outside the measured window
        let mut trace = TraceGenerator::new(
            TraceConfig { max_new_tokens: max_new, ..Default::default() },
            0,
        );
        let requests = trace.take(n);
        let s = pipeline::run(&cfg, &requests).expect("run");
        eprintln!(
            "  step {step}: {:>8.2} samples/s  ({})",
            s.samples_per_sec, name
        );
        report.push(LadderRow {
            step,
            method: name.to_string(),
            dtype: s.dtype.label().to_string(),
            speed: s.samples_per_sec,
            latency_ms: s.latency.mean().as_secs_f64() * 1e3,
            accuracy: s.mean_accuracy,
        });
    }
    println!("\n# Table 1 (reproduced; {n} requests, max_new={max_new})\n");
    println!("{}", report.render());
    let base = report.rows[0].speed.max(1e-9);
    println!(
        "total speedup: {:.2}x (paper: 8.96x on GPU testbed)",
        report.rows.last().unwrap().speed / base
    );
}
